//! The MLP network representation (FANN's `struct fann`, idiomatically).
//!
//! A network is a chain of fully-connected layers; layer `l` maps
//! `sizes[l]` inputs to `sizes[l+1]` outputs through a row-major weight
//! matrix (`w[out][in]`, matching the MCU memory layout the paper streams
//! neuron-by-neuron) plus a bias per output neuron, followed by an
//! activation. This mirrors Eq. (1) of the paper.
//!
//! The forward path dispatches through the crate-wide kernel layer
//! ([`crate::kernels`]): the dense inner loop lives in exactly one place
//! per implementation strategy ([`crate::kernels::BlockedF32`] is the
//! default here), shared with the fixed-point network and the deployment
//! simulator. `runtime::` executes the AOT-compiled JAX version of the
//! same math; parity tests pin all paths together.

use anyhow::{ensure, Result};

use super::activation::Activation;
use crate::kernels::{self, BatchScratch, DenseKernel, DenseLayerRef};
use crate::util::rng::Rng;

// The 4-lane dot product used by the default kernel; re-exported from
// its new home so existing `fann::net::dot_f32` callers keep working.
pub use crate::kernels::dot_f32;

/// One fully-connected layer.
#[derive(Debug, Clone)]
pub struct Layer {
    /// Input width of this layer.
    pub n_in: usize,
    /// Output rows of this layer.
    pub n_out: usize,
    /// Row-major `[n_out][n_in]`: `weights[o * n_in + i]`. Row-major per
    /// output neuron is exactly the order the paper's neuron-wise DMA
    /// streams weights in.
    pub weights: Vec<f32>,
    /// One bias per output row.
    pub biases: Vec<f32>,
    /// Activation applied at the layer output.
    pub activation: Activation,
    /// Uniform activation steepness `s` (output = act(s · sum)).
    pub steepness: f32,
}

impl Layer {
    /// All-zero layer of the given shape.
    pub fn zeros(n_in: usize, n_out: usize, activation: Activation) -> Self {
        Self {
            n_in,
            n_out,
            weights: vec![0.0; n_in * n_out],
            biases: vec![0.0; n_out],
            activation,
            steepness: 1.0,
        }
    }

    /// Borrowed kernel view of this layer's parameters.
    #[inline]
    pub fn as_kernel_ref(&self) -> DenseLayerRef<'_, f32> {
        DenseLayerRef::new(self.n_in, self.n_out, &self.weights, &self.biases)
    }

    /// Forward one sample through the default kernel. `input.len() ==
    /// n_in`, writes `n_out` outputs.
    pub fn forward_into(&self, input: &[f32], out: &mut [f32]) {
        self.forward_into_with(kernels::default_f32(), input, out);
    }

    /// Forward one sample through an explicit [`DenseKernel`]: one
    /// fused `matvec_act` call — the kernel computes the affine part
    /// and applies the activation (with steepness) at write-back, while
    /// the accumulator is still in registers (kernels without a fused
    /// override fall back to matvec + a second sweep, numerically
    /// identical).
    pub fn forward_into_with(&self, kernel: &dyn DenseKernel<f32>, input: &[f32], out: &mut [f32]) {
        debug_assert_eq!(input.len(), self.n_in);
        debug_assert_eq!(out.len(), self.n_out);
        kernel.matvec_act(&self.as_kernel_ref(), input, out, self.activation, self.steepness);
    }

    /// Batched forward: `xs` packs `n_samples` rows of `n_in` values,
    /// `out` receives `n_samples` rows of `n_out` values. Activation is
    /// fused into the kernel's batched pass.
    pub fn forward_batch_with(
        &self,
        kernel: &dyn DenseKernel<f32>,
        xs: &[f32],
        n_samples: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(xs.len(), self.n_in * n_samples);
        debug_assert_eq!(out.len(), self.n_out * n_samples);
        kernel.matmul_act(
            &self.as_kernel_ref(),
            xs,
            n_samples,
            out,
            self.activation,
            self.steepness,
        );
    }

    /// Number of weights (excluding biases).
    pub fn num_weights(&self) -> usize {
        self.weights.len()
    }

    /// Multiply-accumulate count of this layer.
    pub fn macs(&self) -> usize {
        self.n_in * self.n_out
    }
}

/// A multi-layer perceptron.
#[derive(Debug, Clone)]
pub struct Network {
    /// Dense layers in execution order.
    pub layers: Vec<Layer>,
}

impl Network {
    /// Build a network from layer sizes `[in, h1, ..., out]` with zeroed
    /// parameters.
    pub fn new(sizes: &[usize], hidden_act: Activation, output_act: Activation) -> Result<Self> {
        ensure!(sizes.len() >= 2, "need at least input and output layers");
        ensure!(sizes.iter().all(|&s| s > 0), "zero-width layer");
        let last = sizes.len() - 2;
        let layers = sizes
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                Layer::zeros(w[0], w[1], if i == last { output_act } else { hidden_act })
            })
            .collect();
        Ok(Self { layers })
    }

    /// FANN-style random init: weights uniform in `[-limit, +limit]`
    /// (FANN's `fann_randomize_weights`); biases zero. The default limit
    /// mirrors Glorot scaling per layer when `limit` is `None` (what
    /// FANNTool's "smart" init does and what the JAX path uses).
    pub fn randomize(&mut self, rng: &mut Rng, limit: Option<f32>) {
        for layer in &mut self.layers {
            let lim = limit
                .unwrap_or_else(|| (6.0 / (layer.n_in + layer.n_out) as f32).sqrt());
            for w in &mut layer.weights {
                *w = rng.range_f32(-lim, lim);
            }
            for b in &mut layer.biases {
                *b = 0.0;
            }
        }
    }

    /// Layer sizes `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![self.layers[0].n_in];
        sizes.extend(self.layers.iter().map(|l| l.n_out));
        sizes
    }

    /// Input width of the network.
    pub fn num_inputs(&self) -> usize {
        self.layers[0].n_in
    }

    /// Output width of the network.
    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    /// Total weights (excluding biases) — `N_weights` in Eq. (2).
    pub fn num_weights(&self) -> usize {
        self.layers.iter().map(Layer::num_weights).sum()
    }

    /// Total neurons including the per-layer bias pseudo-neuron — the
    /// paper's `N_neurons` convention for Eq. (2).
    pub fn num_neurons_with_bias(&self) -> usize {
        // input layer + its bias, then every layer's outputs + bias.
        let sizes = self.layer_sizes();
        sizes.iter().map(|s| s + 1).sum()
    }

    /// Total number of FANN layers (input + hidden + output) — Eq. (2)'s
    /// `N_fann_layers`.
    pub fn num_fann_layers(&self) -> usize {
        self.layers.len() + 1
    }

    /// Total multiply-accumulates for one inference.
    pub fn macs(&self) -> usize {
        self.layers.iter().map(Layer::macs).sum()
    }

    /// Widest layer input length (drives the scratch buffer in Eq. (2)).
    pub fn max_layer_width(&self) -> usize {
        self.layer_sizes().into_iter().max().unwrap()
    }

    /// Run one sample through the network.
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        let mut scratch = Scratch::for_network(self);
        self.run_with(&mut scratch, input).to_vec()
    }

    /// Run with caller-provided scratch (allocation-free hot path).
    pub fn run_with<'s>(&self, scratch: &'s mut Scratch, input: &[f32]) -> &'s [f32] {
        assert_eq!(input.len(), self.num_inputs());
        scratch.a[..input.len()].copy_from_slice(input);
        let mut cur_len = input.len();
        let mut flip = false;
        for layer in &self.layers {
            let (src, dst) = if flip {
                (&scratch.b, &mut scratch.a)
            } else {
                (&scratch.a, &mut scratch.b)
            };
            layer.forward_into(&src[..cur_len], &mut dst[..layer.n_out]);
            cur_len = layer.n_out;
            flip = !flip;
        }
        let buf = if flip { &scratch.b } else { &scratch.a };
        &buf[..cur_len]
    }

    /// Run one sample through an explicit kernel (parity tests and bench
    /// sweeps; `run` itself uses the crate default). A batch of one:
    /// kernels keep per-sample results bit-identical across batch sizes,
    /// so this IS the single-sample semantics.
    pub fn run_with_kernel(&self, kernel: &dyn DenseKernel<f32>, input: &[f32]) -> Vec<f32> {
        self.run_batch_with_kernel(kernel, input, 1)
    }

    /// Run `n_samples` inputs (packed row-major: `n_samples × n_in`)
    /// through the network in one batched pass; returns `n_samples ×
    /// n_out` outputs, bit-identical to `n_samples` independent [`run`]
    /// calls (`Self::run`). This is the throughput entry point: the
    /// batched kernels reuse each weight row across samples instead of
    /// re-streaming the whole matrix per sample.
    pub fn run_batch(&self, inputs: &[f32], n_samples: usize) -> Vec<f32> {
        self.run_batch_with_kernel(kernels::default_f32(), inputs, n_samples)
    }

    /// [`run_batch`](Self::run_batch) through an explicit kernel.
    /// Allocates only the output vector: the inter-layer ping-pong
    /// buffers come from this thread's persistent [`BatchScratch`]
    /// arena, so repeated same-shape calls perform no scratch
    /// (re)allocation — `rust/tests/batch_scratch.rs` pins this.
    pub fn run_batch_with_kernel(
        &self,
        kernel: &dyn DenseKernel<f32>,
        inputs: &[f32],
        n_samples: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; n_samples * self.num_outputs()];
        kernels::with_thread_scratch_f32(|scratch| {
            self.run_batch_into(kernel, inputs, n_samples, scratch, &mut out)
        });
        out
    }

    /// The allocation-free batched forward: `inputs` packs `n_samples`
    /// rows of `n_in` values, `out` (length `n_samples × n_out`)
    /// receives the outputs. Inter-layer activations ping-pong through
    /// `scratch`, which is grown once to `max_layer_width × n_samples`
    /// per buffer and then only sliced; the first layer reads straight
    /// from `inputs` and the last writes straight into `out`, so the
    /// seed path's input copy and output `to_vec` are gone too.
    pub fn run_batch_into(
        &self,
        kernel: &dyn DenseKernel<f32>,
        inputs: &[f32],
        n_samples: usize,
        scratch: &mut BatchScratch<f32>,
        out: &mut [f32],
    ) {
        assert_eq!(inputs.len(), n_samples * self.num_inputs());
        assert_eq!(out.len(), n_samples * self.num_outputs());
        if n_samples == 0 {
            return;
        }
        let n_layers = self.layers.len();
        let width = self.max_layer_width();
        let (a, b) = scratch.buffers(width * n_samples);
        let mut cur = self.num_inputs();
        for (li, layer) in self.layers.iter().enumerate() {
            let last = li + 1 == n_layers;
            let (src, dst) = kernels::batch_route(li, last, inputs, a, b, out);
            layer.forward_batch_with(
                kernel,
                &src[..cur * n_samples],
                n_samples,
                &mut dst[..layer.n_out * n_samples],
            );
            cur = layer.n_out;
        }
    }

    /// Compile this network into an ahead-of-time execution plan:
    /// concrete kernel, fused epilogue and a contiguous parameter arena
    /// resolved once ([`crate::kernels::ExecPlan`]), with zero per-call
    /// dispatch and a row-split multicore path. Output is bit-identical
    /// to [`run_batch`](Self::run_batch).
    pub fn compile_plan(&self) -> kernels::ExecPlan {
        kernels::ExecPlan::compile(self)
    }

    /// Forward pass retaining every layer's output (for backprop). Returns
    /// `outputs[l]` = activations of layer l (l = 0 is the input itself).
    pub fn forward_trace(&self, input: &[f32]) -> Vec<Vec<f32>> {
        let mut outs = Vec::with_capacity(self.layers.len() + 1);
        outs.push(input.to_vec());
        for layer in &self.layers {
            let mut next = vec![0.0; layer.n_out];
            layer.forward_into(outs.last().unwrap(), &mut next);
            outs.push(next);
        }
        outs
    }
}

/// Double buffer sized for the widest layer — the software analogue of the
/// paper's ping-pong activation buffers (`2 · L_data_buffer` in Eq. (2)).
#[derive(Debug, Clone)]
pub struct Scratch {
    a: Vec<f32>,
    b: Vec<f32>,
}

impl Scratch {
    /// Scratch sized for the widest layer of `net`.
    pub fn for_network(net: &Network) -> Self {
        let w = net.max_layer_width();
        Self {
            a: vec![0.0; w],
            b: vec![0.0; w],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        // 2-2-1, hand-set weights: first layer identity-ish, linear acts.
        let mut net = Network::new(&[2, 2, 1], Activation::Linear, Activation::Linear).unwrap();
        net.layers[0].weights = vec![1.0, 0.0, 0.0, 1.0];
        net.layers[0].biases = vec![0.5, -0.5];
        net.layers[1].weights = vec![2.0, 3.0];
        net.layers[1].biases = vec![1.0];
        net
    }

    #[test]
    fn forward_linear_math() {
        let net = tiny();
        // h = [x0+0.5, x1-0.5]; y = 2h0 + 3h1 + 1
        let y = net.run(&[1.0, 2.0]);
        assert_eq!(y, vec![2.0 * 1.5 + 3.0 * 1.5 + 1.0]);
    }

    #[test]
    fn run_with_matches_run() {
        let mut rng = Rng::new(5);
        let mut net =
            Network::new(&[5, 7, 3], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let x: Vec<f32> = (0..5).map(|i| i as f32 * 0.3 - 0.7).collect();
        let mut scratch = Scratch::for_network(&net);
        let a = net.run(&x);
        let b = net.run_with(&mut scratch, &x).to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn run_batch_matches_single_runs_bitwise() {
        let mut rng = Rng::new(13);
        let mut net = Network::new(&[5, 9, 3], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let n = 6;
        let xs: Vec<f32> = (0..n * 5).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let batched = net.run_batch(&xs, n);
        assert_eq!(batched.len(), n * 3);
        for s in 0..n {
            let single = net.run(&xs[s * 5..(s + 1) * 5]);
            assert_eq!(&batched[s * 3..(s + 1) * 3], &single[..], "sample {s}");
        }
        assert!(net.run_batch(&[], 0).is_empty());
    }

    #[test]
    fn run_batch_into_matches_run_batch_all_depths() {
        // Depth 1 (input straight to out), 2 (one scratch hop) and 4
        // (full ping-pong) all agree with the Vec-returning path.
        let mut rng = Rng::new(31);
        for sizes in [vec![4usize, 3], vec![4, 6, 3], vec![4, 5, 6, 5, 3]] {
            let mut net =
                Network::new(&sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
            net.randomize(&mut rng, None);
            let n = 5;
            let xs: Vec<f32> = (0..n * 4).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let want = net.run_batch(&xs, n);
            let mut scratch = crate::kernels::BatchScratch::new();
            let mut got = vec![0.0f32; n * 3];
            net.run_batch_into(crate::kernels::default_f32(), &xs, n, &mut scratch, &mut got);
            assert_eq!(got, want, "sizes {sizes:?}");
            // Empty batch is a no-op.
            net.run_batch_into(crate::kernels::default_f32(), &[], 0, &mut scratch, &mut []);
        }
    }

    #[test]
    fn counts_match_paper_conventions() {
        // Application A topology: 76-300-200-100-10 => 103800 MACs.
        let net = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        assert_eq!(net.macs(), 103_800);
        assert_eq!(net.num_weights(), 103_800);
        assert_eq!(net.num_fann_layers(), 5);
        assert_eq!(net.num_neurons_with_bias(), 76 + 300 + 200 + 100 + 10 + 5);
        assert_eq!(net.max_layer_width(), 300);
    }

    #[test]
    fn forward_trace_layers() {
        let net = tiny();
        let trace = net.forward_trace(&[1.0, 2.0]);
        assert_eq!(trace.len(), 3);
        assert_eq!(trace[0], vec![1.0, 2.0]);
        assert_eq!(trace[1], vec![1.5, 1.5]);
        assert_eq!(trace[2], net.run(&[1.0, 2.0]));
    }

    #[test]
    fn rejects_degenerate_shapes() {
        assert!(Network::new(&[3], Activation::Tanh, Activation::Sigmoid).is_err());
        assert!(Network::new(&[3, 0, 2], Activation::Tanh, Activation::Sigmoid).is_err());
    }

    #[test]
    fn randomize_within_limit() {
        let mut rng = Rng::new(9);
        let mut net = Network::new(&[4, 4, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, Some(0.1));
        for l in &net.layers {
            assert!(l.weights.iter().all(|w| w.abs() <= 0.1));
            assert!(l.biases.iter().all(|&b| b == 0.0));
        }
    }
}
