//! Fixed-point network conversion — `fann_save_to_fixed` semantics.
//!
//! Converts a trained float [`Network`] to a [`FixedNetwork`]: a single
//! network-wide decimal point is chosen from the largest parameter
//! magnitude and worst-case layer accumulation (see
//! [`crate::quantize::choose_decimal_point`]); all weights/biases are
//! quantized to Q(dec) i32. Inference then runs entirely in integer
//! arithmetic with FANN's step-linear activation approximations —
//! the path FPU-less MCUs (Cortex-M0, IBEX) execute.

use anyhow::Result;

use super::activation::Activation;
use super::net::Network;
use crate::kernels::{DenseKernel, DenseLayerRef, FixedQ};
use crate::quantize;

/// One quantized layer (row-major weights like the float layer).
#[derive(Debug, Clone)]
pub struct FixedLayer {
    pub n_in: usize,
    pub n_out: usize,
    pub weights: Vec<i32>,
    pub biases: Vec<i32>,
    pub activation: Activation,
}

impl FixedLayer {
    /// Borrowed kernel view of this layer's parameters.
    #[inline]
    pub fn as_kernel_ref(&self) -> DenseLayerRef<'_, i32> {
        DenseLayerRef::new(self.n_in, self.n_out, &self.weights, &self.biases)
    }

    /// Forward one quantized sample: kernel affine part, then the
    /// step-linear activation. The decimal point comes from the kernel
    /// itself — the shift amount defines the arithmetic, so affine and
    /// activation can never disagree on it.
    pub fn forward_into_with(&self, kernel: &FixedQ, x_q: &[i32], out: &mut [i32]) {
        kernel.matvec(&self.as_kernel_ref(), x_q, out);
        for v in out.iter_mut() {
            *v = quantize::activation_q(self.activation, *v as i64, kernel.dec) as i32;
        }
    }

    /// Batched forward over `n_samples` packed rows.
    pub fn forward_batch_with(&self, kernel: &FixedQ, xs_q: &[i32], n_samples: usize, out: &mut [i32]) {
        kernel.matmul(&self.as_kernel_ref(), xs_q, n_samples, out);
        for v in out.iter_mut() {
            *v = quantize::activation_q(self.activation, *v as i64, kernel.dec) as i32;
        }
    }
}

/// A fully quantized network.
#[derive(Debug, Clone)]
pub struct FixedNetwork {
    pub layers: Vec<FixedLayer>,
    /// Network-wide decimal point (Q(dec)).
    pub decimal_point: u32,
}

impl FixedNetwork {
    /// Quantize a float network. `max_abs_input` bounds the inputs the
    /// deployed net will see (1.0 for normalized data); it participates in
    /// the overflow analysis exactly like FANN's input-rescaling step.
    pub fn from_float(net: &Network, max_abs_input: f32) -> Result<Self> {
        let mut max_abs_w = 0f32;
        for layer in &net.layers {
            for w in layer.weights.iter().chain(layer.biases.iter()) {
                max_abs_w = max_abs_w.max(w.abs());
            }
        }
        // Bound on any layer input: the raw input bound or an activation
        // output bound (sigmoid/tanh are within [-1, 1]).
        let mut max_abs_x = max_abs_input;
        for layer in &net.layers {
            let (lo, hi) = layer.activation.output_range();
            if lo.is_finite() && hi.is_finite() {
                max_abs_x = max_abs_x.max(lo.abs().max(hi.abs()));
            } else {
                // Unbounded activation (linear/relu): fall back to a
                // conservative bound used by FANN's analysis.
                max_abs_x = max_abs_x.max(8.0);
            }
        }
        let max_fan_in = net.layers.iter().map(|l| l.n_in).max().unwrap();
        let dec = quantize::choose_decimal_point(max_abs_w, max_fan_in, max_abs_x);
        Ok(Self::from_float_with_dec(net, dec))
    }

    /// Quantize with an explicit decimal point (parity tests use this).
    pub fn from_float_with_dec(net: &Network, dec: u32) -> Self {
        let layers = net
            .layers
            .iter()
            .map(|l| FixedLayer {
                n_in: l.n_in,
                n_out: l.n_out,
                // Steepness is folded into the weights at conversion time
                // (w·s), matching how FANN bakes steepness into the
                // fixed-point export.
                weights: l
                    .weights
                    .iter()
                    .map(|&w| quantize::quantize(w * l.steepness, dec))
                    .collect(),
                biases: l
                    .biases
                    .iter()
                    .map(|&b| quantize::quantize(b * l.steepness, dec))
                    .collect(),
                activation: l.activation,
            })
            .collect();
        Self {
            layers,
            decimal_point: dec,
        }
    }

    pub fn num_inputs(&self) -> usize {
        self.layers[0].n_in
    }

    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![self.layers[0].n_in];
        sizes.extend(self.layers.iter().map(|l| l.n_out));
        sizes
    }

    pub fn max_layer_width(&self) -> usize {
        self.layer_sizes().into_iter().max().unwrap()
    }

    /// Quantize a float input vector to the network's Q format.
    pub fn quantize_input(&self, input: &[f32]) -> Vec<i32> {
        input
            .iter()
            .map(|&v| quantize::quantize(v, self.decimal_point))
            .collect()
    }

    /// Run one (already quantized) sample; returns Q(dec) outputs.
    /// Dispatches through the [`FixedQ`] kernel — a batch of one
    /// (integer accumulation makes batching bit-invisible).
    pub fn run_q(&self, input_q: &[i32]) -> Vec<i32> {
        self.run_batch_q(input_q, 1)
    }

    /// Batched quantized inference: `inputs_q` packs `n_samples` rows of
    /// `n_in` Q(dec) values; returns `n_samples × n_out` Q(dec) outputs,
    /// bit-exact with `n_samples` independent [`run_q`](Self::run_q)
    /// calls (integer accumulation commutes; the batched kernel only
    /// reorders weight reuse).
    pub fn run_batch_q(&self, inputs_q: &[i32], n_samples: usize) -> Vec<i32> {
        assert_eq!(inputs_q.len(), n_samples * self.num_inputs());
        if n_samples == 0 {
            return Vec::new();
        }
        let kernel = FixedQ::new(self.decimal_point);
        let width = self.max_layer_width();
        let mut a = vec![0i32; width * n_samples];
        let mut b = vec![0i32; width * n_samples];
        a[..inputs_q.len()].copy_from_slice(inputs_q);
        let mut cur = self.num_inputs();
        let mut flip = false;
        for layer in &self.layers {
            let (src, dst) = if flip { (&b, &mut a) } else { (&a, &mut b) };
            layer.forward_batch_with(
                &kernel,
                &src[..cur * n_samples],
                n_samples,
                &mut dst[..layer.n_out * n_samples],
            );
            cur = layer.n_out;
            flip = !flip;
        }
        let buf = if flip { &b } else { &a };
        buf[..cur * n_samples].to_vec()
    }

    /// Run a float sample end to end: quantize, infer, dequantize.
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        self.run_q(&self.quantize_input(input))
            .into_iter()
            .map(|q| quantize::dequantize(q as i64, self.decimal_point))
            .collect()
    }

    /// Batched float-in/float-out inference: quantize `n_samples` packed
    /// rows, run the batched Q path, dequantize.
    pub fn run_batch(&self, inputs: &[f32], n_samples: usize) -> Vec<f32> {
        self.run_batch_q(&self.quantize_input(inputs), n_samples)
            .into_iter()
            .map(|v| quantize::dequantize(v as i64, self.decimal_point))
            .collect()
    }

    /// Total weights (for Eq. (2) memory estimation of the fixed net).
    pub fn num_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::data::TrainData;
    use crate::fann::train::{accuracy, rprop::Rprop, rprop::RpropConfig};
    use crate::util::rng::Rng;

    fn trained_xor() -> Network {
        let mut rng = Rng::new(42);
        let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        let mut tr = Rprop::new(&net, RpropConfig::default());
        tr.train_until(&mut net, &d, 500, 0.001);
        net
    }

    #[test]
    fn fixed_xor_matches_float_decisions() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        for (x, want) in [
            ([0.0f32, 0.0], 0.0f32),
            ([0.0, 1.0], 1.0),
            ([1.0, 0.0], 1.0),
            ([1.0, 1.0], 0.0),
        ] {
            let y = fixed.run(&x)[0];
            assert_eq!(y >= 0.5, want >= 0.5, "x={x:?} y={y}");
        }
    }

    #[test]
    fn fixed_outputs_close_to_float() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        for x in [[0.0f32, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]] {
            let yf = net.run(&x)[0];
            let yq = fixed.run(&x)[0];
            assert!((yf - yq).abs() < 0.06, "x={x:?} float {yf} fixed {yq}");
        }
    }

    #[test]
    fn batched_fixed_inference_bit_exact() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let xs = [0.0f32, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| quantize::quantize(v, fixed.decimal_point))
            .collect();
        let batched = fixed.run_batch_q(&q, 4);
        assert_eq!(batched.len(), 4);
        for s in 0..4 {
            let single = fixed.run_q(&q[s * 2..(s + 1) * 2]);
            assert_eq!(batched[s], single[0], "sample {s}");
        }
        // Float-in/float-out wrapper agrees with per-sample run().
        let fbatch = fixed.run_batch(&xs, 4);
        for s in 0..4 {
            let single = fixed.run(&xs[s * 2..(s + 1) * 2]);
            assert_eq!(fbatch[s], single[0]);
        }
    }

    #[test]
    fn decimal_point_in_valid_range() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        assert!((1..=20).contains(&fixed.decimal_point));
    }

    #[test]
    fn accuracy_preserved_on_random_classifier() {
        // Train a small classifier on separable blobs; quantization must
        // not change accuracy by more than a few percent.
        let mut rng = Rng::new(77);
        let mut data = TrainData::new(4, 2);
        for i in 0..200 {
            let c = i % 2;
            let mu = if c == 0 { -0.5 } else { 0.5 };
            let x: Vec<f32> = (0..4).map(|_| rng.normal_f32(mu, 0.3)).collect();
            let t = if c == 0 { [1.0, 0.0] } else { [0.0, 1.0] };
            data.push(&x, &t);
        }
        let mut net = Network::new(&[4, 8, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let mut tr = Rprop::new(&net, RpropConfig::default());
        tr.train_until(&mut net, &data, 100, 0.01);
        let acc_f = accuracy(&net, &data);
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let mut correct = 0;
        for i in 0..data.len() {
            let out = fixed.run(data.input(i));
            let pred = crate::util::argmax(&out);
            if pred == data.label(i) {
                correct += 1;
            }
        }
        let acc_q = correct as f32 / data.len() as f32;
        assert!(
            (acc_f - acc_q).abs() < 0.05,
            "float acc {acc_f} vs fixed acc {acc_q}"
        );
    }
}
