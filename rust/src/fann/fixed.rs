//! Fixed-point network conversion — `fann_save_to_fixed` semantics.
//!
//! Converts a trained float [`Network`] to a [`FixedNetwork`]: a single
//! network-wide decimal point is chosen from the largest parameter
//! magnitude and worst-case layer accumulation (see
//! [`crate::quantize::choose_decimal_point`]); all weights/biases are
//! quantized to Q(dec) i32. Inference then runs entirely in integer
//! arithmetic with FANN's step-linear activation approximations —
//! the path FPU-less MCUs (Cortex-M0, IBEX) execute.

use anyhow::Result;

use super::activation::Activation;
use super::net::Network;
use crate::kernels::layout::{pack_rows, PackedPanels, PackedWidth};
use crate::kernels::{
    self, BatchScratch, DenseKernel, DenseLayerRef, FixedQ, PackedLayerRef, PackedQ15, PackedQ7,
};
use crate::quantize;

/// One quantized layer (row-major weights like the float layer).
#[derive(Debug, Clone)]
pub struct FixedLayer {
    /// Input width of this layer.
    pub n_in: usize,
    /// Output rows of this layer.
    pub n_out: usize,
    /// Row-major `[n_out][n_in]` Q(dec) weights.
    pub weights: Vec<i32>,
    /// One Q(dec) bias per output row.
    pub biases: Vec<i32>,
    /// Activation applied at the layer output.
    pub activation: Activation,
}

impl FixedLayer {
    /// Borrowed kernel view of this layer's parameters.
    #[inline]
    pub fn as_kernel_ref(&self) -> DenseLayerRef<'_, i32> {
        DenseLayerRef::new(self.n_in, self.n_out, &self.weights, &self.biases)
    }

    /// Forward one quantized sample: one fused `matvec_act` call — the
    /// kernel computes the affine part and applies the step-linear
    /// activation at write-back. The decimal point comes from the
    /// kernel itself — the shift amount defines the arithmetic, so
    /// affine and activation can never disagree on it.
    pub fn forward_into_with(&self, kernel: &FixedQ, x_q: &[i32], out: &mut [i32]) {
        kernel.matvec_act(&self.as_kernel_ref(), x_q, out, self.activation, 1.0);
    }

    /// Batched forward over `n_samples` packed rows, activation fused.
    pub fn forward_batch_with(&self, kernel: &FixedQ, xs_q: &[i32], n_samples: usize, out: &mut [i32]) {
        kernel.matmul_act(&self.as_kernel_ref(), xs_q, n_samples, out, self.activation, 1.0);
    }
}

/// A fully quantized network.
#[derive(Debug, Clone)]
pub struct FixedNetwork {
    /// Dense layers in execution order.
    pub layers: Vec<FixedLayer>,
    /// Network-wide decimal point (Q(dec)).
    pub decimal_point: u32,
}

impl FixedNetwork {
    /// Quantize a float network. `max_abs_input` bounds the inputs the
    /// deployed net will see (1.0 for normalized data); it participates in
    /// the overflow analysis exactly like FANN's input-rescaling step.
    pub fn from_float(net: &Network, max_abs_input: f32) -> Result<Self> {
        let dec = overflow_decimal_point(net, max_abs_input);
        Ok(Self::from_float_with_dec(net, dec))
    }

    /// Quantize with an explicit decimal point (parity tests use this).
    pub fn from_float_with_dec(net: &Network, dec: u32) -> Self {
        let layers = net
            .layers
            .iter()
            .map(|l| FixedLayer {
                n_in: l.n_in,
                n_out: l.n_out,
                // Steepness is folded into the weights at conversion time
                // (w·s), matching how FANN bakes steepness into the
                // fixed-point export.
                weights: l
                    .weights
                    .iter()
                    .map(|&w| quantize::quantize(w * l.steepness, dec))
                    .collect(),
                biases: l
                    .biases
                    .iter()
                    .map(|&b| quantize::quantize(b * l.steepness, dec))
                    .collect(),
                activation: l.activation,
            })
            .collect();
        Self {
            layers,
            decimal_point: dec,
        }
    }

    /// Input width of the network.
    pub fn num_inputs(&self) -> usize {
        self.layers[0].n_in
    }

    /// Output width of the network.
    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().n_out
    }

    /// Layer sizes `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![self.layers[0].n_in];
        sizes.extend(self.layers.iter().map(|l| l.n_out));
        sizes
    }

    /// Widest layer (sizes the ping-pong buffers).
    pub fn max_layer_width(&self) -> usize {
        self.layer_sizes().into_iter().max().unwrap()
    }

    /// Quantize a float input vector to the network's Q format.
    pub fn quantize_input(&self, input: &[f32]) -> Vec<i32> {
        input
            .iter()
            .map(|&v| quantize::quantize(v, self.decimal_point))
            .collect()
    }

    /// Run one (already quantized) sample; returns Q(dec) outputs.
    /// Dispatches through the [`FixedQ`] kernel — a batch of one
    /// (integer accumulation makes batching bit-invisible).
    pub fn run_q(&self, input_q: &[i32]) -> Vec<i32> {
        self.run_batch_q(input_q, 1)
    }

    /// Batched quantized inference: `inputs_q` packs `n_samples` rows of
    /// `n_in` Q(dec) values; returns `n_samples × n_out` Q(dec) outputs,
    /// bit-exact with `n_samples` independent [`run_q`](Self::run_q)
    /// calls (integer accumulation commutes; the batched kernel only
    /// reorders weight reuse). Allocates only the output vector — the
    /// inter-layer buffers come from this thread's persistent
    /// [`BatchScratch`] arena.
    pub fn run_batch_q(&self, inputs_q: &[i32], n_samples: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_samples * self.num_outputs()];
        kernels::with_thread_scratch_i32(|scratch| {
            self.run_batch_q_into(inputs_q, n_samples, scratch, &mut out)
        });
        out
    }

    /// Allocation-free batched quantized inference into a caller buffer
    /// (`out.len() == n_samples × n_out`), ping-ponging inter-layer
    /// activations through `scratch` — the Q-format twin of
    /// [`Network::run_batch_into`].
    pub fn run_batch_q_into(
        &self,
        inputs_q: &[i32],
        n_samples: usize,
        scratch: &mut BatchScratch<i32>,
        out: &mut [i32],
    ) {
        assert_eq!(inputs_q.len(), n_samples * self.num_inputs());
        assert_eq!(out.len(), n_samples * self.num_outputs());
        if n_samples == 0 {
            return;
        }
        let kernel = FixedQ::new(self.decimal_point);
        let n_layers = self.layers.len();
        let width = self.max_layer_width();
        let (a, b) = scratch.buffers(width * n_samples);
        let mut cur = self.num_inputs();
        for (li, layer) in self.layers.iter().enumerate() {
            let last = li + 1 == n_layers;
            let (src, dst) = kernels::batch_route(li, last, inputs_q, a, b, out);
            layer.forward_batch_with(
                &kernel,
                &src[..cur * n_samples],
                n_samples,
                &mut dst[..layer.n_out * n_samples],
            );
            cur = layer.n_out;
        }
    }

    /// Run a float sample end to end: quantize, infer, dequantize.
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        self.run_q(&self.quantize_input(input))
            .into_iter()
            .map(|q| quantize::dequantize(q as i64, self.decimal_point))
            .collect()
    }

    /// Batched float-in/float-out inference: quantize `n_samples` packed
    /// rows, run the batched Q path, dequantize.
    pub fn run_batch(&self, inputs: &[f32], n_samples: usize) -> Vec<f32> {
        self.run_batch_q(&self.quantize_input(inputs), n_samples)
            .into_iter()
            .map(|v| quantize::dequantize(v as i64, self.decimal_point))
            .collect()
    }

    /// Total weights (for Eq. (2) memory estimation of the fixed net).
    pub fn num_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights.len()).sum()
    }

    /// Compile into an ahead-of-time execution plan
    /// ([`crate::kernels::ExecPlan`]): static kernel dispatch, a
    /// contiguous Q(dec) arena, and the compile-time narrow-multiply
    /// resolution. Bit-exact vs [`run_batch_q`](Self::run_batch_q).
    pub fn compile_plan(&self) -> kernels::ExecPlan {
        kernels::ExecPlan::compile(self)
    }

    /// Offline pack step (the load-time conversion the ISSUE's paper
    /// analogy calls neuron-wise DMA layout): convert every layer's
    /// row-major Q(dec) weights into [`PackedPanels`] at `width`.
    /// Lossless or an error — quantize with
    /// [`packable_decimal_point`] first so the weights fit.
    pub fn pack(&self, width: PackedWidth) -> Result<PackedNetwork> {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                Ok(PackedLayer {
                    panels: pack_rows(width, l.n_in, l.n_out, &l.weights)?,
                    biases: l.biases.clone(),
                    activation: l.activation,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(PackedNetwork {
            layers,
            decimal_point: self.decimal_point,
            width,
        })
    }
}

/// Largest steepness-folded parameter magnitude, `max |p · s|` —
/// folded because `from_float_with_dec` quantizes `p · steepness`, so
/// the folded value is what must be representable. `weights_only`
/// selects the packed-width bound (biases stay wide i32 in
/// [`PackedLayer`], so a large bias must not cost weight bits).
fn max_abs_folded(net: &Network, weights_only: bool) -> f32 {
    let mut max_abs = 0f32;
    for layer in &net.layers {
        for w in &layer.weights {
            max_abs = max_abs.max((w * layer.steepness).abs());
        }
        if !weights_only {
            for b in &layer.biases {
                max_abs = max_abs.max((b * layer.steepness).abs());
            }
        }
    }
    max_abs
}

/// The FANN-style overflow analysis both quantization entry points
/// share ([`FixedNetwork::from_float`] and [`packable_decimal_point`]):
/// bound layer inputs by the raw input bound or the activation output
/// range (8.0 fallback for unbounded linear/relu), then pick the
/// decimal point from the worst-case accumulation over the widest
/// fan-in ([`quantize::choose_decimal_point`]).
fn overflow_decimal_point(net: &Network, max_abs_input: f32) -> u32 {
    let max_abs_w = max_abs_folded(net, false);
    let mut max_abs_x = max_abs_input;
    for layer in &net.layers {
        let (lo, hi) = layer.activation.output_range();
        if lo.is_finite() && hi.is_finite() {
            max_abs_x = max_abs_x.max(lo.abs().max(hi.abs()));
        } else {
            max_abs_x = max_abs_x.max(8.0);
        }
    }
    let max_fan_in = net.layers.iter().map(|l| l.n_in).max().unwrap();
    quantize::choose_decimal_point(max_abs_w, max_fan_in, max_abs_x)
}

/// The largest decimal point at which `net` both passes the shared
/// overflow analysis ([`overflow_decimal_point`]) *and* has every
/// steepness-folded **weight** representable at the narrow packed
/// width — so `FixedNetwork::from_float_with_dec(net, dec)` followed
/// by [`FixedNetwork::pack`] is lossless. May return 0 (pure-integer
/// weights) when the largest weight only fits the narrow width with no
/// fractional bits; a network whose weights exceed the width even at
/// dec 0 makes [`FixedNetwork::pack`] report an error.
pub fn packable_decimal_point(net: &Network, max_abs_input: f32, width: PackedWidth) -> u32 {
    let dec = overflow_decimal_point(net, max_abs_input);
    dec.min(width.max_dec_for(max_abs_folded(net, true)))
}

/// Quantize a float network at a width-representable decimal point and
/// pack it, returning both forms: the [`FixedNetwork`] is the wide
/// reference the packed one is bit-exact against (same dec, same
/// arithmetic), and what the parity tests compare.
pub fn from_float_packed(
    net: &Network,
    max_abs_input: f32,
    width: PackedWidth,
) -> Result<(FixedNetwork, PackedNetwork)> {
    let dec = packable_decimal_point(net, max_abs_input, width);
    let fixed = FixedNetwork::from_float_with_dec(net, dec);
    let packed = fixed.pack(width)?;
    Ok((fixed, packed))
}

/// One layer in packed-panel form: narrow word-packed weights, wide
/// i32 biases (CMSIS-NN keeps bias wide too).
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// Word-packed weight panels.
    pub panels: PackedPanels,
    /// Wide i32 biases (one per output row).
    pub biases: Vec<i32>,
    /// Activation applied at the layer output.
    pub activation: Activation,
}

/// A fully packed network: the deployment form of [`FixedNetwork`] for
/// the low-bitwidth kernels. Inference is bit-exact with the
/// `FixedNetwork` it was packed from (same decimal point, same
/// per-product arithmetic — see [`crate::kernels::packed`]).
#[derive(Debug, Clone)]
pub struct PackedNetwork {
    /// Packed dense layers in execution order.
    pub layers: Vec<PackedLayer>,
    /// Shared Q-format decimal point.
    pub decimal_point: u32,
    /// Packed element width (q7 or q15).
    pub width: PackedWidth,
}

impl PackedNetwork {
    /// Input width of the network.
    pub fn num_inputs(&self) -> usize {
        self.layers[0].panels.n_in
    }

    /// Output width of the network.
    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().panels.n_out
    }

    /// Layer sizes `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![self.layers[0].panels.n_in];
        sizes.extend(self.layers.iter().map(|l| l.panels.n_out));
        sizes
    }

    /// Widest layer (sizes the ping-pong buffers).
    pub fn max_layer_width(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.panels.n_in.max(l.panels.n_out))
            .max()
            .unwrap()
    }

    /// Compile into an ahead-of-time execution plan with the panel
    /// words of every layer copied into one flat word arena
    /// ([`crate::kernels::ExecPlan`]). Bit-exact vs
    /// [`run_batch_q`](Self::run_batch_q).
    pub fn compile_plan(&self) -> kernels::ExecPlan {
        kernels::ExecPlan::compile(self)
    }

    /// Packed parameter bytes (words + wide biases) — the
    /// bytes-per-network column of the bench JSON,
    /// ~4× (Q7) / ~2× (Q15) smaller than the i32 forms.
    pub fn param_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.panels.weight_bytes() + l.biases.len() * 4)
            .sum()
    }

    /// Quantize a float input vector to the network's Q format.
    pub fn quantize_input(&self, input: &[f32]) -> Vec<i32> {
        input
            .iter()
            .map(|&v| quantize::quantize(v, self.decimal_point))
            .collect()
    }

    /// Run one (already quantized) sample; returns Q(dec) outputs.
    pub fn run_q(&self, input_q: &[i32]) -> Vec<i32> {
        self.run_batch_q(input_q, 1)
    }

    /// Batched quantized inference through the packed kernels; output
    /// is bit-exact with [`FixedNetwork::run_batch_q`] on the source
    /// network. Allocates only the output vector.
    pub fn run_batch_q(&self, inputs_q: &[i32], n_samples: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_samples * self.num_outputs()];
        kernels::with_thread_scratch_i32(|scratch| {
            self.run_batch_q_into(inputs_q, n_samples, scratch, &mut out)
        });
        out
    }

    /// Allocation-free batched packed inference (see
    /// [`FixedNetwork::run_batch_q_into`]).
    pub fn run_batch_q_into(
        &self,
        inputs_q: &[i32],
        n_samples: usize,
        scratch: &mut BatchScratch<i32>,
        out: &mut [i32],
    ) {
        assert_eq!(inputs_q.len(), n_samples * self.num_inputs());
        assert_eq!(out.len(), n_samples * self.num_outputs());
        if n_samples == 0 {
            return;
        }
        let q7 = PackedQ7::new(self.decimal_point);
        let q15 = PackedQ15::new(self.decimal_point);
        let n_layers = self.layers.len();
        let width = self.max_layer_width();
        let (a, b) = scratch.buffers(width * n_samples);
        let mut cur = self.num_inputs();
        for (li, layer) in self.layers.iter().enumerate() {
            let last = li + 1 == n_layers;
            let (src, dst) = kernels::batch_route(li, last, inputs_q, a, b, out);
            let pref = PackedLayerRef::new(&layer.panels, &layer.biases);
            let src = &src[..cur * n_samples];
            let dst = &mut dst[..layer.panels.n_out * n_samples];
            match self.width {
                PackedWidth::Q7 => q7.matmul_act(&pref, src, n_samples, dst, layer.activation),
                PackedWidth::Q15 => q15.matmul_act(&pref, src, n_samples, dst, layer.activation),
            }
            cur = layer.panels.n_out;
        }
    }

    /// Run a float sample end to end: quantize, infer, dequantize.
    pub fn run(&self, input: &[f32]) -> Vec<f32> {
        ensure_len(input.len(), self.num_inputs());
        self.run_q(&self.quantize_input(input))
            .into_iter()
            .map(|q| quantize::dequantize(q as i64, self.decimal_point))
            .collect()
    }
}

fn ensure_len(got: usize, want: usize) {
    assert_eq!(got, want, "input length {got} != network inputs {want}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fann::data::TrainData;
    use crate::fann::train::{accuracy, rprop::Rprop, rprop::RpropConfig};
    use crate::util::rng::Rng;

    fn trained_xor() -> Network {
        let mut rng = Rng::new(42);
        let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let mut d = TrainData::new(2, 1);
        d.push(&[0.0, 0.0], &[0.0]);
        d.push(&[0.0, 1.0], &[1.0]);
        d.push(&[1.0, 0.0], &[1.0]);
        d.push(&[1.0, 1.0], &[0.0]);
        let mut tr = Rprop::new(&net, RpropConfig::default());
        tr.train_until(&mut net, &d, 500, 0.001);
        net
    }

    #[test]
    fn fixed_xor_matches_float_decisions() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        for (x, want) in [
            ([0.0f32, 0.0], 0.0f32),
            ([0.0, 1.0], 1.0),
            ([1.0, 0.0], 1.0),
            ([1.0, 1.0], 0.0),
        ] {
            let y = fixed.run(&x)[0];
            assert_eq!(y >= 0.5, want >= 0.5, "x={x:?} y={y}");
        }
    }

    #[test]
    fn fixed_outputs_close_to_float() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        for x in [[0.0f32, 0.0], [0.0, 1.0], [1.0, 0.0], [1.0, 1.0]] {
            let yf = net.run(&x)[0];
            let yq = fixed.run(&x)[0];
            assert!((yf - yq).abs() < 0.06, "x={x:?} float {yf} fixed {yq}");
        }
    }

    #[test]
    fn batched_fixed_inference_bit_exact() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let xs = [0.0f32, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| quantize::quantize(v, fixed.decimal_point))
            .collect();
        let batched = fixed.run_batch_q(&q, 4);
        assert_eq!(batched.len(), 4);
        for s in 0..4 {
            let single = fixed.run_q(&q[s * 2..(s + 1) * 2]);
            assert_eq!(batched[s], single[0], "sample {s}");
        }
        // Float-in/float-out wrapper agrees with per-sample run().
        let fbatch = fixed.run_batch(&xs, 4);
        for s in 0..4 {
            let single = fixed.run(&xs[s * 2..(s + 1) * 2]);
            assert_eq!(fbatch[s], single[0]);
        }
    }

    #[test]
    fn packed_network_bit_exact_vs_fixed_reference() {
        let net = trained_xor();
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (fixed, packed) = from_float_packed(&net, 1.0, width).unwrap();
            assert_eq!(fixed.decimal_point, packed.decimal_point);
            let xs = [0.0f32, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
            let q: Vec<i32> = xs
                .iter()
                .map(|&v| quantize::quantize(v, fixed.decimal_point))
                .collect();
            assert_eq!(
                packed.run_batch_q(&q, 4),
                fixed.run_batch_q(&q, 4),
                "{width:?}"
            );
            // Packed storage is genuinely smaller than the i32 form.
            let wide_bytes =
                4 * (fixed.num_weights() + fixed.layers.iter().map(|l| l.biases.len()).sum::<usize>());
            assert!(packed.param_bytes() < wide_bytes, "{width:?}");
            // XOR decisions survive the narrow quantization.
            for (x, want) in [
                ([0.0f32, 0.0], 0.0f32),
                ([0.0, 1.0], 1.0),
                ([1.0, 0.0], 1.0),
                ([1.0, 1.0], 0.0),
            ] {
                let y = packed.run(&x)[0];
                assert_eq!(y >= 0.5, want >= 0.5, "{width:?} x={x:?} y={y}");
            }
        }
    }

    #[test]
    fn packable_decimal_point_fits_width() {
        let net = trained_xor();
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let dec = packable_decimal_point(&net, 1.0, width);
            let fixed = FixedNetwork::from_float_with_dec(&net, dec);
            for l in &fixed.layers {
                assert!(width.fits(&l.weights), "{width:?} dec={dec}");
            }
            assert!(dec <= 20);
        }
    }

    #[test]
    fn packable_decimal_point_handles_wide_weights_and_biases() {
        // A weight of 100 fits Q7 only at dec 0 — the chosen dec must
        // drop to 0 and still pack losslessly (regression: a dec>=1
        // floor used to force round(100·2)=200 > 127 and fail pack()).
        let mut net = Network::new(&[2, 1], Activation::Linear, Activation::Linear).unwrap();
        net.layers[0].weights = vec![100.0, -90.0];
        net.layers[0].biases = vec![0.25];
        let (fixed, packed) = from_float_packed(&net, 1.0, PackedWidth::Q7).unwrap();
        assert_eq!(fixed.decimal_point, 0);
        assert_eq!(packed.layers[0].panels.unpack(), vec![100, -90]);

        // A big *bias* must not shrink the weights' fractional bits:
        // biases stay wide i32, only weights bind the width constraint.
        let mut net = Network::new(&[2, 1], Activation::Linear, Activation::Linear).unwrap();
        net.layers[0].weights = vec![0.5, -0.5];
        net.layers[0].biases = vec![50.0];
        let dec = packable_decimal_point(&net, 1.0, PackedWidth::Q7);
        assert!(dec >= 4, "bias should not bind the width constraint (dec={dec})");
        assert!(net.layers[0].weights.iter().all(|&w| {
            let q = quantize::quantize(w, dec);
            PackedWidth::Q7.fits(&[q])
        }));
    }

    #[test]
    fn pack_rejects_unrepresentable_weights() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        // The default decimal point targets i32; q7 packing of those
        // wide weights must fail loudly rather than truncate.
        if fixed.layers.iter().any(|l| !PackedWidth::Q7.fits(&l.weights)) {
            assert!(fixed.pack(PackedWidth::Q7).is_err());
        }
    }

    #[test]
    fn decimal_point_in_valid_range() {
        let net = trained_xor();
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        assert!((1..=20).contains(&fixed.decimal_point));
    }

    #[test]
    fn accuracy_preserved_on_random_classifier() {
        // Train a small classifier on separable blobs; quantization must
        // not change accuracy by more than a few percent.
        let mut rng = Rng::new(77);
        let mut data = TrainData::new(4, 2);
        for i in 0..200 {
            let c = i % 2;
            let mu = if c == 0 { -0.5 } else { 0.5 };
            let x: Vec<f32> = (0..4).map(|_| rng.normal_f32(mu, 0.3)).collect();
            let t = if c == 0 { [1.0, 0.0] } else { [0.0, 1.0] };
            data.push(&x, &t);
        }
        let mut net = Network::new(&[4, 8, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let mut tr = Rprop::new(&net, RpropConfig::default());
        tr.train_until(&mut net, &data, 100, 0.01);
        let acc_f = accuracy(&net, &data);
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let mut correct = 0;
        for i in 0..data.len() {
            let out = fixed.run(data.input(i));
            let pred = crate::util::argmax(&out);
            if pred == data.label(i) {
                correct += 1;
            }
        }
        let acc_q = correct as f32 / data.len() as f32;
        assert!(
            (acc_f - acc_q).abs() < 0.05,
            "float acc {acc_f} vs fixed acc {acc_q}"
        );
    }
}
