//! Cascade training — FANN's automatic topology construction
//! (`fann_cascadetrain_on_data`), summarized in the paper's Sec. II-B:
//! "starts with an empty neural network and then adds neurons one by
//! one, while it trains the neural network".
//!
//! We implement the practical variant FANN users rely on for sizing
//! MCU-deployable MLPs: grow one hidden layer neuron-at-a-time. Each
//! round trains a pool of candidate neurons to correlate with the
//! network's residual error (cascade-correlation, Fahlman & Lebiere),
//! installs the best candidate, then retrains the output layer with
//! iRPROP−. Growth stops when the target MSE is reached, the neuron
//! budget is exhausted, or a round stops improving.
//!
//! The result is a standard single-hidden-layer [`Network`], so the
//! whole deployment pipeline (quantization, placement, codegen,
//! simulation) applies unchanged — cascade-built networks can be sized
//! directly against a target's memory budget (see
//! [`CascadeConfig::max_neurons_for_target`]).

use anyhow::Result;

use super::activation::Activation;
use super::data::TrainData;
use super::net::{Layer, Network};
use super::train::rprop::{Rprop, RpropConfig};
use crate::util::rng::Rng;

/// Cascade training configuration (names follow FANN's
/// `fann_set_cascade_*` parameters where they correspond).
#[derive(Debug, Clone, Copy)]
pub struct CascadeConfig {
    /// Maximum hidden neurons to install.
    pub max_neurons: usize,
    /// Candidate pool size per round (FANN default: 2 groups x 4).
    pub num_candidates: usize,
    /// Epochs of candidate correlation training per round.
    pub candidate_epochs: usize,
    /// Epochs of output-layer retraining after each installation.
    pub output_epochs: usize,
    /// Stop when dataset MSE falls below this.
    pub desired_error: f32,
    /// Stop early if a round improves MSE by less than this fraction.
    pub min_improvement: f32,
    /// Activation of installed hidden neurons.
    pub hidden_activation: Activation,
    /// Activation of the output layer.
    pub output_activation: Activation,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        Self {
            max_neurons: 32,
            num_candidates: 8,
            candidate_epochs: 60,
            output_epochs: 60,
            desired_error: 0.001,
            min_improvement: 1e-4,
            hidden_activation: Activation::Tanh,
            output_activation: Activation::Sigmoid,
        }
    }
}

impl CascadeConfig {
    /// Largest hidden-layer width whose Eq. (2) estimate still fits the
    /// given memory budget — lets cascade growth respect an MCU target
    /// up front (the toolkit's angle on cascade training).
    pub fn max_neurons_for_target(
        inputs: usize,
        outputs: usize,
        budget_bytes: usize,
        dtype: crate::targets::DataType,
    ) -> usize {
        let mut hi = 1usize;
        while hi < 100_000 {
            let shape = crate::deploy::NetShape::new(&[inputs, hi, outputs]);
            if crate::deploy::estimate_memory(&shape, dtype) > budget_bytes {
                return hi.saturating_sub(1).max(1);
            }
            hi += 1;
        }
        hi
    }
}

/// One candidate hidden neuron being trained on the residual error.
struct Candidate {
    weights: Vec<f32>, // input weights
    bias: f32,
    correlation: f32,
}

/// Report of a cascade run.
#[derive(Debug, Clone)]
pub struct CascadeReport {
    /// MSE after each installed neuron (index 0 = before any hidden
    /// neuron, outputs trained directly on inputs).
    pub mse_curve: Vec<f32>,
    /// Hidden neurons the run installed.
    pub neurons_installed: usize,
    /// Whether the target error stopped the run early.
    pub stopped_early: bool,
}

/// Grow and train a single-hidden-layer network on `data`.
pub fn cascade_train(
    data: &TrainData,
    config: CascadeConfig,
    rng: &mut Rng,
) -> Result<(Network, CascadeReport)> {
    let n_in = data.num_inputs;
    let n_out = data.num_outputs;

    // Start with a direct input->output network ("empty" in FANN terms:
    // no hidden neurons yet) and train its outputs.
    let mut net = Network::new(&[n_in, n_out], config.hidden_activation, config.output_activation)?;
    net.randomize(rng, None);
    train_outputs(&mut net, data, config.output_epochs);
    let mut mse_curve = vec![super::train::mse(&net, data)];

    // FANN's cascade keeps input->output shortcut connections; a plain
    // MLP cannot, so a small hidden bottleneck can transiently be worse
    // than the direct network. We therefore track and return the best
    // network seen across growth (the curve still records every round).
    let mut best_net = net.clone();
    let mut best_mse = mse_curve[0];

    let mut stopped_early = false;
    let mut hidden: Vec<(Vec<f32>, f32)> = Vec::new(); // (weights, bias)

    while hidden.len() < config.max_neurons {
        if best_mse <= config.desired_error {
            break;
        }
        // Residual errors of the current network per sample/output.
        let residuals = residual_errors(&net, data);

        // Train a candidate pool to maximize correlation with the
        // residual; install the best.
        let best = train_candidates(data, &residuals, &config, rng);
        hidden.push((best.weights, best.bias));

        // Rebuild as [in, hidden.len(), out] and retrain the outputs
        // (installed hidden weights are frozen — cascade-correlation).
        net = assemble(n_in, n_out, &hidden, config)?;
        net.randomize_outputs_only(rng);
        train_outputs(&mut net, data, config.output_epochs);

        let mse = super::train::mse(&net, data);
        let prev = *mse_curve.last().unwrap();
        mse_curve.push(mse);
        if mse < best_mse {
            best_mse = mse;
            best_net = net.clone();
        }
        if hidden.len() > 1 && prev - mse < config.min_improvement * prev.max(1e-9) {
            stopped_early = true;
            break;
        }
    }

    let report = CascadeReport {
        neurons_installed: hidden.len(),
        mse_curve,
        stopped_early,
    };
    Ok((best_net, report))
}

/// Per-sample, per-output residual errors (out - target) of the current
/// network.
fn residual_errors(net: &Network, data: &TrainData) -> Vec<f32> {
    let mut scratch = super::net::Scratch::for_network(net);
    let mut res = Vec::with_capacity(data.len() * data.num_outputs);
    for i in 0..data.len() {
        let out = net.run_with(&mut scratch, data.input(i));
        for (o, t) in out.iter().zip(data.target(i)) {
            res.push(o - t);
        }
    }
    res
}

/// Cascade-correlation candidate training: gradient ascent on the
/// covariance between the candidate's output and the residual error.
fn train_candidates(
    data: &TrainData,
    residuals: &[f32],
    config: &CascadeConfig,
    rng: &mut Rng,
) -> Candidate {
    let n_in = data.num_inputs;
    let n_out = data.num_outputs;
    let n = data.len();
    let lr = 0.05f32;

    let mut best = Candidate {
        weights: vec![0.0; n_in],
        bias: 0.0,
        correlation: f32::NEG_INFINITY,
    };

    for _ in 0..config.num_candidates {
        let limit = (6.0 / (n_in + 1) as f32).sqrt();
        let mut w: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-limit, limit)).collect();
        let mut b = 0.0f32;

        for _ in 0..config.candidate_epochs {
            // Candidate outputs and their mean.
            let mut vs = Vec::with_capacity(n);
            for i in 0..n {
                let mut acc = b;
                for (wi, xi) in w.iter().zip(data.input(i)) {
                    acc += wi * xi;
                }
                vs.push(config.hidden_activation.apply(acc));
            }
            let v_mean: f32 = vs.iter().sum::<f32>() / n as f32;

            // Covariance per output; gradient of sum_o |cov_o| wrt w.
            let mut dw = vec![0.0f32; n_in];
            let mut db = 0.0f32;
            for o in 0..n_out {
                let mut cov = 0.0f32;
                for i in 0..n {
                    cov += (vs[i] - v_mean) * residuals[i * n_out + o];
                }
                let sign = if cov >= 0.0 { 1.0 } else { -1.0 };
                for i in 0..n {
                    let dv = config.hidden_activation.grad_from_output(vs[i]);
                    let g = sign * residuals[i * n_out + o] * dv;
                    for (k, xi) in data.input(i).iter().enumerate() {
                        dw[k] += g * xi;
                    }
                    db += g;
                }
            }
            let scale = lr / n as f32;
            for (wk, dk) in w.iter_mut().zip(&dw) {
                *wk += scale * dk;
            }
            b += scale * db;
        }

        // Final correlation score.
        let mut vs = Vec::with_capacity(n);
        for i in 0..n {
            let mut acc = b;
            for (wi, xi) in w.iter().zip(data.input(i)) {
                acc += wi * xi;
            }
            vs.push(config.hidden_activation.apply(acc));
        }
        let v_mean: f32 = vs.iter().sum::<f32>() / n as f32;
        let mut score = 0.0f32;
        for o in 0..n_out {
            let mut cov = 0.0f32;
            for i in 0..n {
                cov += (vs[i] - v_mean) * residuals[i * n_out + o];
            }
            score += cov.abs();
        }
        if score > best.correlation {
            best = Candidate {
                weights: w,
                bias: b,
                correlation: score,
            };
        }
    }
    best
}

/// Build the [in, |hidden|, out] network with the frozen hidden neurons.
fn assemble(
    n_in: usize,
    n_out: usize,
    hidden: &[(Vec<f32>, f32)],
    config: CascadeConfig,
) -> Result<Network> {
    let h = hidden.len();
    let mut net = Network::new(&[n_in, h, n_out], config.hidden_activation, config.output_activation)?;
    for (j, (w, b)) in hidden.iter().enumerate() {
        net.layers[0].weights[j * n_in..(j + 1) * n_in].copy_from_slice(w);
        net.layers[0].biases[j] = *b;
    }
    Ok(net)
}

/// Output-layer-only iRPROP− (hidden layer frozen), as cascade training
/// prescribes.
fn train_outputs(net: &mut Network, data: &TrainData, epochs: usize) {
    let mut trainer = Rprop::new(net, RpropConfig::default());
    for _ in 0..epochs {
        // Full gradients but only apply the output layer's update: we
        // train a temporary copy and copy the output layer back.
        let frozen: Vec<Layer> = net.layers[..net.layers.len() - 1].to_vec();
        trainer.train_epoch(net, data);
        for (l, layer) in frozen.into_iter().enumerate() {
            net.layers[l] = layer;
        }
    }
}

impl Network {
    /// Re-randomize only the output layer (used between cascade rounds).
    pub(crate) fn randomize_outputs_only(&mut self, rng: &mut Rng) {
        let last = self.layers.len() - 1;
        let layer = &mut self.layers[last];
        let lim = (6.0 / (layer.n_in + layer.n_out) as f32).sqrt();
        for w in &mut layer.weights {
            *w = rng.range_f32(-lim, lim);
        }
        for b in &mut layer.biases {
            *b = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets;

    #[test]
    fn cascade_solves_xor() {
        let data = datasets::xor();
        let mut rng = Rng::new(77);
        let config = CascadeConfig {
            max_neurons: 8,
            desired_error: 0.01,
            ..CascadeConfig::default()
        };
        let (net, report) = cascade_train(&data, config, &mut rng).unwrap();
        assert!(report.neurons_installed >= 1);
        assert!(
            *report.mse_curve.last().unwrap() < 0.05,
            "cascade failed: {:?}",
            report.mse_curve
        );
        // XOR truth table respected.
        for (x, want) in [
            ([0.0f32, 0.0], false),
            ([0.0, 1.0], true),
            ([1.0, 0.0], true),
            ([1.0, 1.0], false),
        ] {
            assert_eq!(net.run(&x)[0] >= 0.5, want, "x={x:?}");
        }
    }

    #[test]
    fn cascade_returns_best_network_seen() {
        let data = datasets::activity(5);
        let mut rng = Rng::new(5);
        let config = CascadeConfig {
            max_neurons: 6,
            candidate_epochs: 30,
            output_epochs: 30,
            desired_error: 1e-6, // force growth to the cap
            min_improvement: 0.0,
            ..CascadeConfig::default()
        };
        let (net, report) = cascade_train(&data, config, &mut rng).unwrap();
        // The returned network is the argmin over every visited
        // configuration — never worse than the direct in->out baseline.
        let returned = crate::fann::train::mse(&net, &data);
        let min = report
            .mse_curve
            .iter()
            .cloned()
            .fold(f32::INFINITY, f32::min);
        assert!((returned - min).abs() < 1e-6, "{returned} vs curve min {min}");
        assert!(returned <= report.mse_curve[0] + 1e-6);
    }

    #[test]
    fn grown_network_deploys_through_toolkit() {
        let data = datasets::xor();
        let mut rng = Rng::new(9);
        let (net, _) = cascade_train(&data, CascadeConfig::default(), &mut rng).unwrap();
        // The cascade output is a plain Network: quantize + place it.
        let fixed = crate::fann::FixedNetwork::from_float(&net, 1.0).unwrap();
        let plan = crate::deploy::plan(
            &crate::deploy::NetShape::from(&fixed),
            crate::targets::Target::WolfFc,
            crate::targets::DataType::Fixed,
        )
        .unwrap();
        assert!(plan.fits());
    }

    #[test]
    fn budget_caps_growth() {
        let cap = CascadeConfig::max_neurons_for_target(
            100,
            8,
            16 * 1024,
            crate::targets::DataType::Fixed,
        );
        // 16 kB / ((100+8)*4B per neuron + overheads) ≈ 30ish.
        assert!((10..60).contains(&cap), "{cap}");
        // Bigger budget, more neurons.
        let cap2 = CascadeConfig::max_neurons_for_target(
            100,
            8,
            64 * 1024,
            crate::targets::DataType::Fixed,
        );
        assert!(cap2 > cap);
    }
}
