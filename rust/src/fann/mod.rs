//! FANN substrate: a Rust reimplementation of the parts of the Fast
//! Artificial Neural Network library the toolkit builds on.
//!
//! * [`net`] — the MLP representation and the reference float inference
//!   path (Eq. 1 of the paper).
//! * [`activation`] — FANN's activation functions and output-derivative
//!   forms.
//! * [`data`] — training data + the FANN `.data` text format.
//! * [`train`] — incremental/batch backprop and iRPROP− (FANN's default).
//! * [`cascade`] — cascade training: automatic topology growth
//!   (`fann_cascadetrain_on_data`).
//! * [`tune`] — FANNTool-style automatic hyper-parameter search.
//! * [`fixed`] — `fann_save_to_fixed`: conversion to Q-format integer
//!   networks for FPU-less targets.
//! * [`io`] — `.net` file formats (float and fixed).

pub mod activation;
pub mod cascade;
pub mod data;
pub mod fixed;
pub mod io;
pub mod net;
pub mod train;
pub mod tune;

pub use activation::Activation;
pub use data::TrainData;
pub use fixed::{from_float_packed, packable_decimal_point, FixedNetwork, PackedNetwork};
pub use net::{Layer, Network, Scratch};
