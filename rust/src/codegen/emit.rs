//! The emit pipeline: trained network → placement → generated C bundle
//! + machine-readable [`DeployPlan`] + a self-contained
//! [`EmittedArtifact`] the [`crate::emulator`] can execute.
//!
//! The artifact owns its parameters (exactly the values the generated
//! `fann_net.h` prints), so `net → emit → emulate` really executes what
//! was emitted rather than silently reading the source network again.

use anyhow::{bail, Result};

use super::plan::{build_deploy_plan, DeployPlan, NetRepr};
use super::{generate, GeneratedCode, NetSource};
use crate::deploy::{self, NetShape};
use crate::fann::activation::Activation;
use crate::fann::{from_float_packed, FixedNetwork, Network};
use crate::kernels::layout::{PackedPanels, PackedWidth};
use crate::targets::Target;

/// One dense layer of an emitted artifact, parameters owned.
#[derive(Debug, Clone)]
pub struct EmittedLayer {
    /// Input width of this layer.
    pub n_in: usize,
    /// Output rows of this layer.
    pub n_out: usize,
    /// Activation applied at the layer output.
    pub activation: Activation,
    /// Owned parameter payload in the emitted representation.
    pub weights: EmittedWeights,
}

/// The parameter payload of one emitted layer, in the representation
/// the artifact was emitted at.
#[derive(Debug, Clone)]
pub enum EmittedWeights {
    /// IEEE f32 parameters.
    F32 {
        /// Row-major `[n_out][n_in]`.
        weights: Vec<f32>,
        /// One bias per output row.
        biases: Vec<f32>,
        /// Activation steepness folded at run time (float path only).
        steepness: f32,
    },
    /// Wide Q(dec) i32 parameters.
    Q32 {
        /// Row-major `[n_out][n_in]` Q(dec) weights.
        weights: Vec<i32>,
        /// One Q(dec) bias per output row.
        biases: Vec<i32>,
    },
    /// Word-panel-packed q7/q15 parameters.
    Packed {
        /// The packed weight panels.
        panels: PackedPanels,
        /// Wide i32 biases (CMSIS-NN keeps bias wide).
        biases: Vec<i32>,
    },
}

/// A self-contained emitted deployment: the plan plus the parameters,
/// enough to execute without the source network.
#[derive(Debug, Clone)]
pub struct EmittedArtifact {
    /// The machine-readable schedule the artifact executes under.
    pub plan: DeployPlan,
    /// Dense layers with owned parameters, in execution order.
    pub layers: Vec<EmittedLayer>,
}

impl EmittedArtifact {
    /// Input width of the emitted network.
    pub fn num_inputs(&self) -> usize {
        self.layers[0].n_in
    }

    /// Output width of the emitted network.
    pub fn num_outputs(&self) -> usize {
        self.layers.last().unwrap().n_out
    }
}

/// The full result of one emit: the C source bundle (including
/// `deploy_plan.json`) and the executable artifact.
#[derive(Debug, Clone)]
pub struct EmitBundle {
    /// The C source bundle plus `deploy_plan.json`.
    pub code: GeneratedCode,
    /// The self-contained executable artifact.
    pub artifact: EmittedArtifact,
}

fn finish_code(
    placement: &crate::deploy::DeploymentPlan,
    source: NetSource,
    plan: &DeployPlan,
) -> GeneratedCode {
    let mut code = generate(placement, source);
    code.files
        .push(("deploy_plan.json".to_string(), plan.to_json().to_pretty()));
    code
}

/// Emit a float-trained network for `target` at representation `repr`.
/// Quantization (q32) and lossless packing (q7/q15, decimal point chosen
/// by [`crate::fann::packable_decimal_point`]) happen here;
/// `max_abs_input` bounds the deployed inputs for the overflow analysis
/// (1.0 for normalized data). Returns a structured error when the
/// target/representation combination is unsupported (float on an
/// FPU-less core), the network does not fit, or the weights cannot be
/// packed losslessly.
pub fn emit_float(
    net: &Network,
    target: Target,
    repr: NetRepr,
    max_abs_input: f32,
) -> Result<EmitBundle> {
    let shape = NetShape::from(net);
    let placement = deploy::plan(&shape, target, repr.dtype())?;
    let acts: Vec<Activation> = net.layers.iter().map(|l| l.activation).collect();

    match repr {
        NetRepr::F32 => {
            let bytes: Vec<usize> = net
                .layers
                .iter()
                .map(|l| (l.weights.len() + l.biases.len()) * 4)
                .collect();
            let plan = build_deploy_plan(&placement, repr, None, &acts, &bytes)?;
            let code = finish_code(&placement, NetSource::Float(net), &plan);
            let layers = net
                .layers
                .iter()
                .map(|l| EmittedLayer {
                    n_in: l.n_in,
                    n_out: l.n_out,
                    activation: l.activation,
                    weights: EmittedWeights::F32 {
                        weights: l.weights.clone(),
                        biases: l.biases.clone(),
                        steepness: l.steepness,
                    },
                })
                .collect();
            Ok(EmitBundle {
                code,
                artifact: EmittedArtifact { plan, layers },
            })
        }
        NetRepr::Q32 => {
            let fixed = FixedNetwork::from_float(net, max_abs_input)?;
            emit_fixed(&fixed, target)
        }
        NetRepr::Q7 | NetRepr::Q15 => {
            let width = if repr == NetRepr::Q7 {
                PackedWidth::Q7
            } else {
                PackedWidth::Q15
            };
            let (_fixed, packed) = from_float_packed(net, max_abs_input, width)?;
            let bytes: Vec<usize> = packed
                .layers
                .iter()
                .map(|l| l.panels.weight_bytes() + l.biases.len() * 4)
                .collect();
            let plan = build_deploy_plan(
                &placement,
                repr,
                Some(packed.decimal_point),
                &acts,
                &bytes,
            )?;
            let code = finish_code(&placement, NetSource::Packed(&packed), &plan);
            let layers = packed
                .layers
                .iter()
                .map(|l| EmittedLayer {
                    n_in: l.panels.n_in,
                    n_out: l.panels.n_out,
                    activation: l.activation,
                    weights: EmittedWeights::Packed {
                        panels: l.panels.clone(),
                        biases: l.biases.clone(),
                    },
                })
                .collect();
            Ok(EmitBundle {
                code,
                artifact: EmittedArtifact { plan, layers },
            })
        }
    }
}

/// Emit an already-quantized network (q32) for `target` — the path a
/// `*_fixed.net` file takes through `deploy emit`.
pub fn emit_fixed(fixed: &FixedNetwork, target: Target) -> Result<EmitBundle> {
    let shape = NetShape::from(fixed);
    let placement = deploy::plan(&shape, target, NetRepr::Q32.dtype())?;
    let acts: Vec<Activation> = fixed.layers.iter().map(|l| l.activation).collect();
    let bytes: Vec<usize> = fixed
        .layers
        .iter()
        .map(|l| (l.weights.len() + l.biases.len()) * 4)
        .collect();
    let plan = build_deploy_plan(
        &placement,
        NetRepr::Q32,
        Some(fixed.decimal_point),
        &acts,
        &bytes,
    )?;
    let code = finish_code(&placement, NetSource::Fixed(fixed), &plan);
    let layers = fixed
        .layers
        .iter()
        .map(|l| EmittedLayer {
            n_in: l.n_in,
            n_out: l.n_out,
            activation: l.activation,
            weights: EmittedWeights::Q32 {
                weights: l.weights.clone(),
                biases: l.biases.clone(),
            },
        })
        .collect();
    Ok(EmitBundle {
        code,
        artifact: EmittedArtifact { plan, layers },
    })
}

/// Emit with a representation chosen for the target: f32 on FPU cores,
/// q32 otherwise (the paper's float-vs-fixed deployment split).
pub fn emit_auto(net: &Network, target: Target, max_abs_input: f32) -> Result<EmitBundle> {
    let repr = if target.supports_float() {
        NetRepr::F32
    } else {
        NetRepr::Q32
    };
    emit_float(net, target, repr, max_abs_input)
}

/// Sanity guard shared by the CLI: packed representations are only
/// meaningful when emitted from a float network (the packer picks the
/// decimal point); a fixed `.net` file deploys as q32.
pub fn repr_for_fixed_source(repr: NetRepr) -> Result<NetRepr> {
    match repr {
        NetRepr::Q32 => Ok(repr),
        other => bail!(
            "a fixed .net source deploys as q32; re-emit from the float .net for {}",
            other.label()
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::targets::Chip;
    use crate::util::rng::Rng;

    fn small_net(sizes: &[usize]) -> Network {
        let mut rng = Rng::new(11);
        let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        net
    }

    #[test]
    fn emit_f32_bundle_contains_plan_json() {
        let net = small_net(&[5, 7, 3]);
        let b = emit_float(&net, Target::CortexM4(Chip::Stm32l475vg), NetRepr::F32, 1.0).unwrap();
        let plan_json = b.code.file("deploy_plan.json").unwrap();
        assert!(plan_json.contains("\"schema\": \"fann-on-mcu/deploy-plan/v1\""));
        assert!(plan_json.contains("\"target\": \"cortex-m4f\""));
        assert_eq!(b.artifact.num_inputs(), 5);
        assert_eq!(b.artifact.num_outputs(), 3);
        assert!(matches!(
            b.artifact.layers[0].weights,
            EmittedWeights::F32 { .. }
        ));
    }

    #[test]
    fn emitted_params_match_net_header_values() {
        // The artifact must carry exactly what fann_net.h prints.
        let net = small_net(&[3, 4, 2]);
        let b = emit_float(&net, Target::WolfFc, NetRepr::Q32, 1.0).unwrap();
        let header = b.code.file("fann_net.h").unwrap();
        match &b.artifact.layers[0].weights {
            EmittedWeights::Q32 { weights, .. } => {
                let first = format!("fann_weights_0[{}]", weights.len());
                assert!(header.contains(&first));
                assert!(header.contains(&weights[0].to_string()));
            }
            other => panic!("expected Q32 weights, got {other:?}"),
        }
    }

    #[test]
    fn emit_packed_records_decimal_point_and_width() {
        let net = small_net(&[6, 8, 3]);
        for repr in [NetRepr::Q7, NetRepr::Q15] {
            let b = emit_float(&net, Target::WolfCluster { cores: 8 }, repr, 1.0).unwrap();
            assert_eq!(b.artifact.plan.repr, repr);
            assert!(b.artifact.plan.decimal_point.is_some());
            assert!(matches!(
                b.artifact.layers[0].weights,
                EmittedWeights::Packed { .. }
            ));
            assert!(b.code.file("fann_conf.h").unwrap().contains("FANN_PACKED_WEIGHT_BITS"));
        }
    }

    #[test]
    fn float_on_fpu_less_target_is_an_error() {
        let net = small_net(&[4, 3, 2]);
        assert!(emit_float(&net, Target::WolfFc, NetRepr::F32, 1.0).is_err());
        // emit_auto falls back to q32 there.
        let b = emit_auto(&net, Target::WolfFc, 1.0).unwrap();
        assert_eq!(b.artifact.plan.repr, NetRepr::Q32);
    }

    #[test]
    fn oversized_network_is_a_structured_error() {
        let net = small_net(&[1024, 2048, 8]);
        let err = emit_float(&net, Target::CortexM4(Chip::Nrf52832), NetRepr::F32, 1.0)
            .unwrap_err();
        assert!(err.to_string().contains("does not fit"));
    }

    #[test]
    fn fixed_source_only_deploys_q32() {
        assert!(repr_for_fixed_source(NetRepr::Q32).is_ok());
        assert!(repr_for_fixed_source(NetRepr::Q7).is_err());
    }
}
