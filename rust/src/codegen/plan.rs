//! The machine-readable deployment plan emitted next to the generated C
//! sources — the contract between the generator, the emulator and CI.
//!
//! [`crate::deploy::placement`] decides *where* the network lives (the
//! Sec. IV-B policy); this module expands that placement into a
//! [`DeployPlan`]: one [`LayerPlan`] per dense layer with its parameter
//! bytes in the emitted representation, the region its parameters rest
//! in, the region the inner loop reads them from, the per-layer DMA
//! double-buffer schedule ([`LayerDma`], from the [`crate::targets::dma`]
//! model) and a per-layer cycle estimate; plus whole-network
//! cycle/time/energy estimates from [`crate::simulator::target_cost`]
//! (Table I ISA costs × [`crate::targets::power`]). `to_json()` renders
//! the plan as the `deploy_plan.json` artifact file.

use anyhow::{bail, ensure, Result};

use crate::deploy::{self, DeploymentPlan, DmaStrategy};
use crate::fann::activation::Activation;
use crate::simulator::{self, cost, CostOptions, TargetCost};
use crate::targets::{Core, DataType, Region, Target};
use crate::util::json::Json;

/// Numeric representation of the emitted network parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetRepr {
    /// IEEE f32 (FPU targets only).
    F32,
    /// Wide Q(dec) i32 fixed point.
    Q32,
    /// 4×i8-per-word packed fixed point (panel layout).
    Q7,
    /// 2×i16-per-word packed fixed point (panel layout).
    Q15,
}

impl NetRepr {
    /// Stable lowercase name (`f32`, `q32`, `q7`, `q15`).
    pub fn label(self) -> &'static str {
        match self {
            NetRepr::F32 => "f32",
            NetRepr::Q32 => "q32",
            NetRepr::Q7 => "q7",
            NetRepr::Q15 => "q15",
        }
    }

    /// Parse a `--repr` CLI value.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" | "float" => NetRepr::F32,
            "q32" | "fixed" => NetRepr::Q32,
            "q7" => NetRepr::Q7,
            "q15" => NetRepr::Q15,
            other => bail!("unknown representation {other:?} (known: f32, q32, q7, q15)"),
        })
    }

    /// The planner dtype this representation deploys as. Packed widths
    /// plan as `Fixed`: the Eq. (2) estimate stays the paper's 4-byte
    /// words (conservative), while the per-layer [`LayerPlan`] records
    /// the actual packed bytes.
    pub fn dtype(self) -> DataType {
        match self {
            NetRepr::F32 => DataType::Float32,
            _ => DataType::Fixed,
        }
    }

    /// MAC operands per inner-loop multiply on `core` for this
    /// representation: the SIMD rungs of Fig. 3 (`pv.sdotsp` packs 4
    /// int8 / 2 int16 MACs on RI5CY; `SMLAD` dual-MACs 16-bit pairs on
    /// the M4/M7, with `SXTB16` making the q7 path dual too). Cores
    /// without packed-SIMD support (M0, IBEX) stay at 1.
    pub fn simd_lanes(self, core: Core) -> u8 {
        match (self, core) {
            (NetRepr::Q7, Core::Riscy) => 4,
            (NetRepr::Q15, Core::Riscy) => 2,
            (NetRepr::Q7 | NetRepr::Q15, Core::CortexM4 | Core::CortexM7) => 2,
            _ => 1,
        }
    }

    /// Cost-model options for this representation on `target`. Packed
    /// representations additionally quantize the parallel row split to
    /// whole 4-row word panels (`row_block`), matching the panel
    /// schedule the emulator walks and the host row-split driver runs.
    pub fn cost_options(self, target: Target) -> CostOptions {
        CostOptions {
            simd_lanes: self.simd_lanes(target.core()),
            row_block: match self {
                NetRepr::Q7 | NetRepr::Q15 => 4,
                _ => 1,
            },
            ..CostOptions::default()
        }
    }
}

/// Per-layer DMA double-buffer schedule entry (cluster targets whose
/// network is shared-L2-resident).
#[derive(Debug, Clone)]
pub struct LayerDma {
    /// Double-buffer granularity (layer-wise or neuron-wise).
    pub granularity: DmaStrategy,
    /// Transfers programmed for this layer (1 for layer-wise, one per
    /// output neuron for neuron-wise).
    pub chunks: usize,
    /// Payload bytes of one transfer in the emitted representation.
    pub chunk_bytes: usize,
    /// L1 ping-pong staging footprint the schedule reserves (2 × chunk
    /// for neuron-wise; 2 × the largest layer for the shared layer-wise
    /// double buffer).
    pub buffer_bytes: usize,
    /// Modeled DMA cycles of this layer (cold start + overlapped
    /// steady-state chunks, from [`crate::targets::dma::WOLF_DMA`]).
    pub est_cycles: f64,
}

/// One dense layer of the deployment plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Dense-layer index (0-based).
    pub index: usize,
    /// Input width of the layer.
    pub n_in: usize,
    /// Output rows of the layer.
    pub n_out: usize,
    /// Activation applied at the layer output.
    pub activation: Activation,
    /// Parameter bytes (weights + biases) in the emitted representation.
    pub param_bytes: usize,
    /// Where the parameters live at rest.
    pub param_region: Region,
    /// Where the inner loop reads them from (L1 when DMA-staged).
    pub compute_region: Region,
    /// DMA schedule entry when this layer streams from L2.
    pub dma: Option<LayerDma>,
    /// Modeled cycles of this layer (compute + overheads + DMA).
    pub est_cycles: f64,
}

/// The machine-readable deployment plan: everything `deploy_plan.json`
/// records and everything the emulator needs to walk the schedule.
#[derive(Debug, Clone)]
pub struct DeployPlan {
    /// The deployment target.
    pub target: Target,
    /// Numeric representation of the emitted parameters.
    pub repr: NetRepr,
    /// Q-format decimal point (fixed-point representations).
    pub decimal_point: Option<u32>,
    /// Where the network parameters live at rest.
    pub region: Region,
    /// Whole-network DMA strategy (cluster L2-resident nets).
    pub dma: Option<DmaStrategy>,
    /// Eq. (2) estimate in bytes (4-byte words, the paper's form).
    pub est_memory_bytes: usize,
    /// Layer sizes `[in, h1, ..., out]`.
    pub sizes: Vec<usize>,
    /// Per-dense-layer schedule, in execution order.
    pub layers: Vec<LayerPlan>,
    /// Whole-network cycle/time/energy estimate (SIMD-aware for packed
    /// representations).
    pub cost: TargetCost,
    /// The raw Sec. IV-B placement this plan expands.
    pub placement: DeploymentPlan,
}

impl DeployPlan {
    /// Total parameter bytes in the emitted representation.
    pub fn param_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.param_bytes).sum()
    }

    /// Resident L1 bytes of the activation ping-pong buffers
    /// (`2 × widest layer` words — Eq. (2)'s data-buffer term).
    pub fn activation_buffer_bytes(&self) -> usize {
        2 * self.sizes.iter().copied().max().unwrap_or(0) * 4
    }

    /// Peak L1 staging footprint of the DMA schedule (0 without DMA).
    pub fn staging_bytes(&self) -> usize {
        self.layers
            .iter()
            .filter_map(|l| l.dma.as_ref().map(|d| d.buffer_bytes))
            .max()
            .unwrap_or(0)
    }
}

/// Expand a Sec. IV-B placement into the full per-layer plan.
///
/// * `repr` / `decimal_point` — the emitted representation;
/// * `acts[l]` — activation of dense layer `l`;
/// * `layer_param_bytes[l]` — that layer's weight+bias bytes **in the
///   emitted representation** (packed widths are smaller than the
///   4-byte words the Eq. (2) estimate assumes).
///
/// Returns a structured error (never panics) when the network does not
/// fit the target or when the schedule would oversubscribe the cluster
/// L1 budget — the satellite contract `rust/tests/prop_placement.rs`
/// pins.
pub fn build_deploy_plan(
    placement: &DeploymentPlan,
    repr: NetRepr,
    decimal_point: Option<u32>,
    acts: &[Activation],
    layer_param_bytes: &[usize],
) -> Result<DeployPlan> {
    let sizes = placement.shape.sizes.clone();
    ensure!(
        acts.len() == sizes.len() - 1 && layer_param_bytes.len() == sizes.len() - 1,
        "plan shape ({} dense layers) does not match activations ({}) / byte table ({})",
        sizes.len() - 1,
        acts.len(),
        layer_param_bytes.len()
    );
    if !placement.fits() {
        bail!(
            "network does not fit {}: Eq. (2) estimates {} bytes and no placement policy \
             (resident / flash-or-L2 / DMA-streamed) accepts it",
            placement.target.label(),
            placement.est_memory_bytes
        );
    }

    let opts = repr.cost_options(placement.target);
    let max_layer_bytes = layer_param_bytes.iter().copied().max().unwrap_or(0);

    let mut layers = Vec::with_capacity(sizes.len() - 1);
    let mut prev_compute = 0.0;
    for (i, w) in sizes.windows(2).enumerate() {
        let b = cost::layer_cycles(placement, w[0], w[1], acts[i], prev_compute, i == 0, opts);
        prev_compute = b.compute;
        let dma = placement.dma.map(|granularity| {
            let (chunks, chunk_bytes, buffer_bytes) = match granularity {
                DmaStrategy::LayerWise => {
                    (1, layer_param_bytes[i], 2 * max_layer_bytes)
                }
                DmaStrategy::NeuronWise => {
                    // One transfer per output neuron; the payload is the
                    // neuron's share of the layer's emitted bytes (its
                    // weight row plus its bias).
                    let per_row = layer_param_bytes[i].div_ceil(w[1]);
                    (w[1], per_row, 2 * per_row)
                }
            };
            LayerDma {
                granularity,
                chunks,
                chunk_bytes,
                buffer_bytes,
                est_cycles: b.dma,
            }
        });
        layers.push(LayerPlan {
            index: i,
            n_in: w[0],
            n_out: w[1],
            activation: acts[i],
            param_bytes: layer_param_bytes[i],
            param_region: placement.region,
            compute_region: if dma.is_some() {
                Region::L1
            } else {
                placement.region
            },
            dma,
            est_cycles: b.total(),
        });
    }

    let cost = simulator::target_cost(placement, acts, opts);
    let plan = DeployPlan {
        target: placement.target,
        repr,
        decimal_point,
        region: placement.region,
        dma: placement.dma,
        est_memory_bytes: placement.est_memory_bytes,
        sizes,
        layers,
        cost,
        placement: placement.clone(),
    };

    // Cluster L1 budget checks the placement policy's Eq. (2) screen
    // cannot see: the DMA staging buffers must coexist with the
    // activation ping-pong buffers in L1.
    if matches!(plan.target, Target::WolfCluster { .. }) {
        let budget = deploy::cluster_l1_budget();
        let resident = match plan.region {
            Region::L1 => plan.param_bytes(),
            _ => plan.staging_bytes(),
        };
        let need = resident + plan.activation_buffer_bytes();
        ensure!(
            need <= budget,
            "DMA/resident schedule oversubscribes cluster L1: {} bytes of parameters/staging \
             + {} bytes of activation buffers > {} byte budget",
            resident,
            plan.activation_buffer_bytes(),
            budget
        );
    }

    Ok(plan)
}

fn region_json(r: Region) -> Json {
    Json::Str(r.name().to_string())
}

fn dma_strategy_name(d: DmaStrategy) -> &'static str {
    match d {
        DmaStrategy::LayerWise => "layer-wise",
        DmaStrategy::NeuronWise => "neuron-wise",
    }
}

impl DeployPlan {
    /// Render the plan as the `deploy_plan.json` artifact (insertion-
    /// ordered keys, deterministic float formatting — see
    /// [`crate::util::json`]).
    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let mut o = Json::obj()
                    .field("index", l.index)
                    .field("n_in", l.n_in)
                    .field("n_out", l.n_out)
                    .field("activation", l.activation.name())
                    .field("param_bytes", l.param_bytes)
                    .field("param_region", region_json(l.param_region))
                    .field("compute_region", region_json(l.compute_region))
                    .field("est_cycles", l.est_cycles);
                o = match &l.dma {
                    Some(d) => o.field(
                        "dma",
                        Json::obj()
                            .field("granularity", dma_strategy_name(d.granularity))
                            .field("chunks", d.chunks)
                            .field("chunk_bytes", d.chunk_bytes)
                            .field("buffer_bytes", d.buffer_bytes)
                            .field("est_cycles", d.est_cycles)
                            .build(),
                    ),
                    None => o.field("dma", Json::Null),
                };
                o.build()
            })
            .collect::<Vec<_>>();

        Json::obj()
            .field("schema", "fann-on-mcu/deploy-plan/v1")
            .field("target", self.target.slug())
            .field("target_label", self.target.label())
            .field("repr", self.repr.label())
            .field(
                "decimal_point",
                match self.decimal_point {
                    Some(d) => Json::Int(d as i64),
                    None => Json::Null,
                },
            )
            .field("region", region_json(self.region))
            .field(
                "dma",
                match self.dma {
                    Some(d) => Json::Str(dma_strategy_name(d).to_string()),
                    None => Json::Null,
                },
            )
            .field("est_memory_bytes", self.est_memory_bytes)
            .field("param_bytes", self.param_bytes())
            .field(
                "layer_sizes",
                Json::Arr(self.sizes.iter().map(|&s| Json::Int(s as i64)).collect()),
            )
            .field("layers", Json::Arr(layers))
            .field(
                "estimate",
                Json::obj()
                    .field("cycles", self.cost.breakdown.total())
                    .field("cycles_compute", self.cost.breakdown.compute)
                    .field("cycles_dma", self.cost.breakdown.dma)
                    .field("cycles_barrier", self.cost.breakdown.barrier)
                    .field("cycles_overhead", self.cost.breakdown.overhead)
                    .field("cycles_activation", self.cost.breakdown.activation)
                    .field("seconds", self.cost.seconds)
                    .field("active_mw", self.cost.active_mw)
                    .field("energy_uj", self.cost.energy_uj)
                    .field("utilization", self.cost.utilization)
                    .field("e2e_seconds", self.cost.e2e_seconds)
                    .field("e2e_energy_uj", self.cost.e2e_energy_uj)
                    .build(),
            )
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::targets::Chip;

    const ACTS: [Activation; 2] = [Activation::Tanh, Activation::Sigmoid];

    fn wide_bytes(sizes: &[usize]) -> Vec<usize> {
        sizes
            .windows(2)
            .map(|w| (w[0] * w[1] + w[1]) * 4)
            .collect()
    }

    #[test]
    fn resident_plan_has_no_dma_and_matches_cost_model() {
        let shape = NetShape::new(&[7, 6, 5]);
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let d = build_deploy_plan(&p, NetRepr::F32, None, &ACTS, &wide_bytes(&shape.sizes))
            .unwrap();
        assert_eq!(d.region, Region::L1);
        assert!(d.layers.iter().all(|l| l.dma.is_none()));
        assert!(d.layers.iter().all(|l| l.compute_region == Region::L1));
        let direct = simulator::target_cost(&p, &ACTS, CostOptions::default());
        assert_eq!(d.cost.breakdown.total(), direct.breakdown.total());
        // Per-layer estimates sum to the network total minus the input
        // DMA-in term the whole-network model adds for cluster runs.
        let layer_sum: f64 = d.layers.iter().map(|l| l.est_cycles).sum();
        assert!(layer_sum <= direct.breakdown.total());
    }

    #[test]
    fn layerwise_schedule_covers_every_layer() {
        let shape = NetShape::new(&[50, 100, 60, 100, 60, 8]);
        let acts = vec![Activation::Tanh; 4]
            .into_iter()
            .chain([Activation::Sigmoid])
            .collect::<Vec<_>>();
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.dma, Some(DmaStrategy::LayerWise));
        let d =
            build_deploy_plan(&p, NetRepr::F32, None, &acts, &wide_bytes(&shape.sizes)).unwrap();
        assert_eq!(d.layers.len(), 5);
        for l in &d.layers {
            let dma = l.dma.as_ref().expect("layer-wise schedule covers all layers");
            assert_eq!(dma.chunks, 1);
            assert_eq!(dma.chunk_bytes, l.param_bytes);
            assert_eq!(l.compute_region, Region::L1);
            assert_eq!(l.param_region, Region::SharedL2);
        }
        // Shared double buffer: 2x the largest layer.
        let max_bytes = d.layers.iter().map(|l| l.param_bytes).max().unwrap();
        assert!(d.layers.iter().all(|l| l.dma.as_ref().unwrap().buffer_bytes == 2 * max_bytes));
        assert!(d.staging_bytes() + d.activation_buffer_bytes() <= deploy::cluster_l1_budget());
    }

    #[test]
    fn neuronwise_schedule_has_one_chunk_per_neuron() {
        let shape = NetShape::new(&[600, 40, 8]);
        let acts = [Activation::Tanh, Activation::Sigmoid];
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        assert_eq!(p.dma, Some(DmaStrategy::NeuronWise));
        let d =
            build_deploy_plan(&p, NetRepr::F32, None, &acts, &wide_bytes(&shape.sizes)).unwrap();
        let l0 = d.layers[0].dma.as_ref().unwrap();
        assert_eq!(l0.chunks, 40);
        assert_eq!(l0.chunk_bytes, ((600 * 40 + 40) * 4usize).div_ceil(40));
        assert_eq!(l0.buffer_bytes, 2 * l0.chunk_bytes);
    }

    #[test]
    fn nofit_is_a_structured_error() {
        let shape = NetShape::new(&[2048, 2048, 8]);
        let p = plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let err = build_deploy_plan(&p, NetRepr::F32, None, &ACTS, &wide_bytes(&shape.sizes))
            .unwrap_err();
        assert!(err.to_string().contains("does not fit"));
    }

    #[test]
    fn packed_repr_uses_simd_lanes_in_estimate() {
        let shape = NetShape::new(&[64, 64, 32]);
        let acts = [Activation::Tanh, Activation::Sigmoid];
        let p = plan(&shape, Target::WolfCluster { cores: 1 }, DataType::Fixed).unwrap();
        let wide =
            build_deploy_plan(&p, NetRepr::Q32, Some(12), &acts, &wide_bytes(&shape.sizes))
                .unwrap();
        // Packed bytes: q7 stores 4 weights per word.
        let packed_bytes: Vec<usize> = shape
            .sizes
            .windows(2)
            .map(|w| w[1].div_ceil(4) * 4 * w[0].div_ceil(4) * 4 + w[1] * 4)
            .collect();
        let q7 = build_deploy_plan(&p, NetRepr::Q7, Some(6), &acts, &packed_bytes).unwrap();
        assert!(q7.cost.breakdown.compute < wide.cost.breakdown.compute);
        assert!(q7.param_bytes() < wide.param_bytes());
    }

    #[test]
    fn repr_parse_round_trips() {
        for r in [NetRepr::F32, NetRepr::Q32, NetRepr::Q7, NetRepr::Q15] {
            assert_eq!(NetRepr::parse(r.label()).unwrap(), r);
        }
        assert!(NetRepr::parse("bf16").is_err());
    }

    #[test]
    fn plan_json_has_schema_and_layers() {
        let shape = NetShape::new(&[5, 4, 3]);
        let p = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let d = build_deploy_plan(&p, NetRepr::Q32, Some(13), &ACTS, &wide_bytes(&shape.sizes))
            .unwrap();
        let text = d.to_json().to_pretty();
        assert!(text.contains("\"schema\": \"fann-on-mcu/deploy-plan/v1\""));
        assert!(text.contains("\"target\": \"wolf-fc\""));
        assert!(text.contains("\"repr\": \"q32\""));
        assert!(text.contains("\"decimal_point\": 13"));
        assert!(text.contains("\"layers\""));
        assert!(text.contains("\"estimate\""));
    }
}
