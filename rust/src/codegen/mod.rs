//! C code generation — the user-visible artifact of the paper's toolkit.
//!
//! The original FANN-on-MCU emits `fann_conf.h` / `fann_net.h` / test
//! data headers plus a platform-tuned `fann.c` inner loop, compiled with
//! arm-gcc or the PULP SDK. We generate the same files as strings (golden
//! tests pin the output); since this reproduction cannot flash silicon,
//! the [`crate::simulator`] executes the identical deployment plan the
//! generated code encodes — same placement, same DMA strategy, same inner
//! loop (Table I).

pub mod arm;
pub mod emit;
pub mod plan;
pub mod pulp;

pub use emit::{
    emit_auto, emit_fixed, emit_float, repr_for_fixed_source, EmitBundle, EmittedArtifact,
    EmittedLayer, EmittedWeights,
};
pub use plan::{build_deploy_plan, DeployPlan, LayerDma, LayerPlan, NetRepr};

use crate::deploy::{DeploymentPlan, DmaStrategy};
use crate::fann::{FixedNetwork, Network, PackedNetwork};
use crate::kernels::layout::{PackedWidth, ROWS_PER_PANEL};
use crate::targets::{DataType, Region, Target};

/// A generated source bundle: `(file name, contents)` pairs.
#[derive(Debug, Clone)]
pub struct GeneratedCode {
    /// Emitted `(file name, contents)` pairs, in write order.
    pub files: Vec<(String, String)>,
}

impl GeneratedCode {
    /// Contents of the emitted file called `name`, if present.
    pub fn file(&self, name: &str) -> Option<&str> {
        self.files
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, c)| c.as_str())
    }

    /// Total size of the bundle in bytes.
    pub fn total_bytes(&self) -> usize {
        self.files.iter().map(|(_, c)| c.len()).sum()
    }
}

/// The network parameters being emitted (float, wide fixed, or packed
/// q7/q15 word-panel form).
pub enum NetSource<'a> {
    /// IEEE f32 parameters from a float network.
    Float(&'a Network),
    /// Wide Q(dec) i32 parameters from a fixed network.
    Fixed(&'a FixedNetwork),
    /// Word-panel-packed q7/q15 parameters.
    Packed(&'a PackedNetwork),
}

impl NetSource<'_> {
    /// Fixed-point decimal point of the emitted parameters, if any.
    pub(crate) fn decimal_point(&self) -> Option<u32> {
        match self {
            NetSource::Float(_) => None,
            NetSource::Fixed(n) => Some(n.decimal_point),
            NetSource::Packed(p) => Some(p.decimal_point),
        }
    }

    /// Packed storage width when the source is word-packed.
    pub(crate) fn packed_width(&self) -> Option<PackedWidth> {
        match self {
            NetSource::Packed(p) => Some(p.width),
            _ => None,
        }
    }
}

/// Generate the deployment bundle for a plan. Dispatches to the ARM or
/// PULP backend; both share the same parameter-emission helpers.
pub fn generate(plan: &DeploymentPlan, net: NetSource) -> GeneratedCode {
    match plan.target {
        Target::CortexM4(_) | Target::CortexM7(_) | Target::CortexM0(_) => {
            arm::generate(plan, &net)
        }
        Target::WolfFc | Target::WolfCluster { .. } => pulp::generate(plan, &net),
    }
}

// ---------------------------------------------------------------------------
// Shared emission helpers (used by both backends)
// ---------------------------------------------------------------------------

pub(crate) fn dtype_c_name(dtype: DataType) -> &'static str {
    match dtype {
        DataType::Float32 => "float",
        DataType::Fixed => "int32_t",
    }
}

pub(crate) fn emit_array_f32(name: &str, vals: &[f32], section: &str) -> String {
    let body = vals
        .iter()
        .map(|v| format!("{v:.8}f"))
        .collect::<Vec<_>>()
        .join(", ");
    format!("static const float {name}[{}] {section} = {{{body}}};\n", vals.len())
}

pub(crate) fn emit_array_i32(name: &str, vals: &[i32], section: &str) -> String {
    let body = vals
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "static const int32_t {name}[{}] {section} = {{{body}}};\n",
        vals.len()
    )
}

pub(crate) fn emit_array_u32_hex(name: &str, vals: &[u32], section: &str) -> String {
    let body = vals
        .iter()
        .map(|v| format!("0x{v:08x}u"))
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "static const uint32_t {name}[{}] {section} = {{{body}}};\n",
        vals.len()
    )
}

/// The linker-section attribute placing parameters per the plan.
pub(crate) fn section_attr(plan: &DeploymentPlan) -> &'static str {
    match plan.region {
        Region::Ram => "",
        Region::Flash => "__attribute__((section(\".rodata\")))",
        Region::PrivateL2 => "__attribute__((section(\".fc_private\")))",
        Region::SharedL2 => "__attribute__((section(\".l2_shared\")))",
        Region::L1 => "__attribute__((section(\".l1_tcdm\")))",
        Region::NoFit => "/* DOES NOT FIT */",
    }
}

/// Config header shared by both backends: network dimensions, placement,
/// DMA strategy — everything the runtime loop needs at compile time.
pub(crate) fn emit_conf_header(plan: &DeploymentPlan, dec: Option<u32>) -> String {
    emit_conf_header_with(plan, dec, None)
}

/// [`emit_conf_header`] plus the packed-width defines when the emitted
/// parameters are q7/q15 word panels.
pub(crate) fn emit_conf_header_with(
    plan: &DeploymentPlan,
    dec: Option<u32>,
    packed: Option<PackedWidth>,
) -> String {
    let sizes = &plan.shape.sizes;
    let mut s = String::new();
    s.push_str("/* Auto-generated by fann-on-mcu. Do not edit. */\n");
    s.push_str("#ifndef FANN_CONF_H\n#define FANN_CONF_H\n\n");
    s.push_str(&format!("#define FANN_NUM_LAYERS {}\n", sizes.len()));
    s.push_str(&format!("#define FANN_NUM_INPUT {}\n", sizes[0]));
    s.push_str(&format!(
        "#define FANN_NUM_OUTPUT {}\n",
        sizes[sizes.len() - 1]
    ));
    s.push_str(&format!(
        "#define FANN_MAX_LAYER_WIDTH {}\n",
        plan.shape.max_layer_width()
    ));
    s.push_str(&format!(
        "#define FANN_LAYER_SIZES {{{}}}\n",
        sizes
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join(", ")
    ));
    s.push_str(&format!(
        "#define FANN_DATA_TYPE {}\n",
        dtype_c_name(plan.dtype)
    ));
    if let Some(dec) = dec {
        s.push_str(&format!("#define FANN_FIXED_DECIMAL_POINT {dec}\n"));
        s.push_str(&format!("#define FANN_FIXED_ONE (1 << {dec})\n"));
    }
    if let Some(width) = packed {
        let bits = match width {
            PackedWidth::Q7 => 8,
            PackedWidth::Q15 => 16,
        };
        s.push_str(&format!("#define FANN_PACKED_WEIGHT_BITS {bits}\n"));
        s.push_str(&format!(
            "#define FANN_PACKED_ROWS_PER_PANEL {ROWS_PER_PANEL}\n"
        ));
    }
    s.push_str(&format!(
        "#define FANN_PLACEMENT_REGION \"{}\"\n",
        plan.region.name()
    ));
    match plan.dma {
        Some(DmaStrategy::LayerWise) => s.push_str("#define FANN_DMA_LAYERWISE 1\n"),
        Some(DmaStrategy::NeuronWise) => s.push_str("#define FANN_DMA_NEURONWISE 1\n"),
        None => s.push_str("/* network resident: no DMA streaming */\n"),
    }
    s.push_str(&format!(
        "#define FANN_EST_MEMORY_BYTES {}\n",
        plan.est_memory_bytes
    ));
    s.push_str("\n#endif /* FANN_CONF_H */\n");
    s
}

/// The packed `fann_run()`, shared by both backends: walks the 4-row
/// panel layout of `fann_net.h` directly — row `o`'s word `c` sits at
/// `panel_base + c · FANN_PACKED_ROWS_PER_PANEL + (o % ROWS_PER_PANEL)`
/// (see [`crate::kernels::layout`]), so the dot helper takes the word
/// stride instead of assuming contiguous rows. `parallel` adds the
/// cluster stripe/fork note.
pub(crate) fn emit_packed_run(parallel: bool) -> String {
    let stripe = if parallel {
        concat!(
            "        /* cluster build: fork this row loop across FANN_NUM_CORES\n",
            "         * (o = rt_core_id() + k * FANN_NUM_CORES stripes) and meet at\n",
            "         * an rt_team_barrier() before the buffer swap; the fork\n",
            "         * skeleton of the float fann_layer_worker applies unchanged. */\n"
        )
    } else {
        ""
    };
    format!(
        r#"/* Auto-generated by fann-on-mcu. Packed fann_run(): output rows are
 * grouped in panels of FANN_PACKED_ROWS_PER_PANEL; within a panel, row
 * r's word c sits at panel_base + c * FANN_PACKED_ROWS_PER_PANEL + r
 * (the forward word stream described in fann_net.h), so the dot helper
 * takes a word stride rather than assuming contiguous rows.
 */
#include <stdint.h>
#include "fann_conf.h"
#include "fann_net.h"

#define FANN_PACKED_LANES (32 / FANN_PACKED_WEIGHT_BITS)

int32_t fann_activation(int32_t x, int layer); /* step-linear tables */
/* Bias is seeded into the i64 accumulator and the sum saturates ONCE at
 * the end — the host PackedQ7/PackedQ15 kernels' exact semantics. */
int32_t fann_dot_packed(const uint32_t *words, uint32_t word_stride,
                        const int32_t *x, uint32_t n, int32_t bias);
const uint32_t *fann_layer_words(uint32_t l);
const int32_t *fann_layer_biases(uint32_t l);

static int32_t fann_buf_a[FANN_MAX_LAYER_WIDTH];
static int32_t fann_buf_b[FANN_MAX_LAYER_WIDTH];

const int32_t *fann_run(const int32_t *input) {{
    static const uint32_t sizes[FANN_NUM_LAYERS] = FANN_LAYER_SIZES;
    const int32_t *cur = input;
    int32_t *next = fann_buf_a;
    for (uint32_t l = 0; l + 1 < FANN_NUM_LAYERS; ++l) {{
        const uint32_t *words = fann_layer_words(l);
        const int32_t *b = fann_layer_biases(l);
        uint32_t wpr = (sizes[l] + FANN_PACKED_LANES - 1) / FANN_PACKED_LANES;
{stripe}        for (uint32_t o = 0; o < sizes[l + 1]; ++o) {{
            const uint32_t *panel = &words[(o / FANN_PACKED_ROWS_PER_PANEL)
                                           * wpr * FANN_PACKED_ROWS_PER_PANEL];
            int32_t acc = fann_dot_packed(&panel[o % FANN_PACKED_ROWS_PER_PANEL],
                                          FANN_PACKED_ROWS_PER_PANEL, cur, sizes[l], b[o]);
            next[o] = fann_activation(acc, l);
        }}
        cur = next;
        next = (next == fann_buf_a) ? fann_buf_b : fann_buf_a;
    }}
    return cur;
}}
"#
    )
}

/// Emit the per-layer parameter arrays (weights row-major per neuron —
/// the order the DMA streams them).
pub(crate) fn emit_net_header(plan: &DeploymentPlan, net: &NetSource) -> String {
    let attr = section_attr(plan);
    let mut s = String::new();
    s.push_str("/* Auto-generated by fann-on-mcu. Do not edit. */\n");
    s.push_str("#ifndef FANN_NET_H\n#define FANN_NET_H\n\n#include <stdint.h>\n#include \"fann_conf.h\"\n\n");
    match net {
        NetSource::Float(n) => {
            for (i, l) in n.layers.iter().enumerate() {
                s.push_str(&emit_array_f32(&format!("fann_weights_{i}"), &l.weights, attr));
                s.push_str(&emit_array_f32(&format!("fann_biases_{i}"), &l.biases, attr));
                s.push_str(&format!(
                    "/* layer {i}: {}x{} act={} steepness={} */\n",
                    l.n_in,
                    l.n_out,
                    l.activation.name(),
                    l.steepness
                ));
            }
        }
        NetSource::Fixed(n) => {
            for (i, l) in n.layers.iter().enumerate() {
                s.push_str(&emit_array_i32(&format!("fann_weights_{i}"), &l.weights, attr));
                s.push_str(&emit_array_i32(&format!("fann_biases_{i}"), &l.biases, attr));
                s.push_str(&format!(
                    "/* layer {i}: {}x{} act={} (Q{}) */\n",
                    l.n_in,
                    l.n_out,
                    l.activation.name(),
                    n.decimal_point
                ));
            }
        }
        NetSource::Packed(p) => {
            for (i, l) in p.layers.iter().enumerate() {
                s.push_str(&emit_array_u32_hex(
                    &format!("fann_weights_{i}"),
                    &l.panels.words,
                    attr,
                ));
                s.push_str(&emit_array_i32(&format!("fann_biases_{i}"), &l.biases, attr));
                s.push_str(&format!(
                    "/* layer {i}: {}x{} act={} ({} word-packed, {} panels of {} rows, {} words/row, Q{}) */\n",
                    l.panels.n_in,
                    l.panels.n_out,
                    l.activation.name(),
                    l.panels.width.label(),
                    l.panels.panels(),
                    ROWS_PER_PANEL,
                    l.panels.words_per_row,
                    p.decimal_point
                ));
            }
        }
    }
    s.push_str("\n#endif /* FANN_NET_H */\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::fann::{Activation, Network};
    use crate::targets::Chip;
    use crate::util::rng::Rng;

    fn small_net() -> Network {
        let mut rng = Rng::new(2);
        let mut net = Network::new(&[4, 5, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        net
    }

    #[test]
    fn conf_header_contains_dimensions() {
        let net = small_net();
        let p = plan(
            &NetShape::from(&net),
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Float32,
        )
        .unwrap();
        let h = emit_conf_header(&p, None);
        assert!(h.contains("#define FANN_NUM_INPUT 4"));
        assert!(h.contains("#define FANN_NUM_OUTPUT 2"));
        assert!(h.contains("#define FANN_DATA_TYPE float"));
        assert!(h.contains("FANN_PLACEMENT_REGION \"RAM\""));
    }

    #[test]
    fn fixed_conf_has_decimal_point() {
        let net = small_net();
        let fixed = crate::fann::FixedNetwork::from_float(&net, 1.0).unwrap();
        let p = plan(&NetShape::from(&fixed), Target::WolfFc, DataType::Fixed).unwrap();
        let h = emit_conf_header(&p, Some(fixed.decimal_point));
        assert!(h.contains(&format!(
            "#define FANN_FIXED_DECIMAL_POINT {}",
            fixed.decimal_point
        )));
        assert!(h.contains("int32_t"));
    }

    #[test]
    fn net_header_array_sizes() {
        let net = small_net();
        let p = plan(
            &NetShape::from(&net),
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Float32,
        )
        .unwrap();
        let h = emit_net_header(&p, &NetSource::Float(&net));
        assert!(h.contains("fann_weights_0[20]"));
        assert!(h.contains("fann_biases_1[2]"));
    }

    #[test]
    fn generate_dispatches_per_target() {
        let net = small_net();
        let shape = NetShape::from(&net);
        let p_arm = plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
        let g = generate(&p_arm, NetSource::Float(&net));
        assert!(g.file("fann_inner_loop.c").unwrap().contains("arm_dot_prod"));
        let p_pulp = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let g = generate(&p_pulp, NetSource::Float(&net));
        assert!(g.file("fann_inner_loop.c").unwrap().contains("plp_"));
    }
}
