//! Deployment execution engine: runs a network *numerically* (float or
//! fixed path — bit-exact with the Pallas kernels) while accounting
//! cycles, time and energy from the [`super::cost`] model.
//!
//! Numeric outputs are target-independent (the same arithmetic runs on
//! every MCU); only the cycle/energy report varies with the plan — which
//! is exactly the paper's premise.

use anyhow::{ensure, Result};

use super::cost::{self, CostOptions, CycleBreakdown};
use crate::deploy::DeploymentPlan;
use crate::fann::activation::Activation;
use crate::fann::{FixedNetwork, Network};
use crate::targets::{power, DataType, Target};

/// The executable forms a deployment can carry.
#[derive(Debug)]
pub enum Executable<'a> {
    Float(&'a Network),
    Fixed(&'a FixedNetwork),
}

impl<'a> Executable<'a> {
    pub fn num_inputs(&self) -> usize {
        match self {
            Executable::Float(n) => n.num_inputs(),
            Executable::Fixed(n) => n.num_inputs(),
        }
    }

    pub fn activations(&self) -> Vec<Activation> {
        match self {
            Executable::Float(n) => n.layers.iter().map(|l| l.activation).collect(),
            Executable::Fixed(n) => n.layers.iter().map(|l| l.activation).collect(),
        }
    }

    pub fn layer_sizes(&self) -> Vec<usize> {
        match self {
            Executable::Float(n) => n.layer_sizes(),
            Executable::Fixed(n) => n.layer_sizes(),
        }
    }
}

/// Result of one simulated classification.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Network outputs (dequantized for fixed-point deployments).
    pub outputs: Vec<f32>,
    /// Cycle breakdown of the compute phase.
    pub breakdown: CycleBreakdown,
    /// Compute-phase wall time at the target's clock.
    pub seconds: f64,
    /// Average power during compute (utilization-aware for the cluster).
    pub active_mw: f64,
    /// Compute-phase energy.
    pub energy_uj: f64,
    /// Core-busy fraction (1.0 for single-core targets).
    pub utilization: f64,
    /// End-to-end time for ONE classification including the one-time
    /// cluster activation/deactivation overhead (Table II footnote).
    pub e2e_seconds: f64,
    /// End-to-end energy for one classification.
    pub e2e_energy_uj: f64,
}

impl SimReport {
    /// Amortized per-classification time when `n` classifications run per
    /// cluster activation (the paper's asymptotic 22× / 14.3× numbers).
    pub fn amortized_seconds(&self, plan_target: Target, n: u64) -> f64 {
        self.seconds + plan_target.fixed_overhead_seconds() / n as f64
    }

    /// Amortized per-classification energy for `n` classifications per
    /// activation.
    pub fn amortized_energy_uj(&self, plan_target: Target, n: u64) -> f64 {
        self.energy_uj
            + power::energy_uj(
                plan_target.fixed_overhead_seconds(),
                plan_target.fixed_overhead_mw(),
            ) / n as f64
    }
}

/// Simulate one classification of `input` under `plan`.
pub fn simulate(
    plan: &DeploymentPlan,
    exe: &Executable,
    input: &[f32],
    opts: CostOptions,
) -> Result<SimReport> {
    ensure!(plan.fits(), "network does not fit {}", plan.target.label());
    ensure!(
        input.len() == exe.num_inputs(),
        "input length {} != network inputs {}",
        input.len(),
        exe.num_inputs()
    );
    ensure!(
        exe.layer_sizes() == plan.shape.sizes,
        "plan shape does not match executable"
    );
    match (&exe, plan.dtype) {
        (Executable::Float(_), DataType::Float32) | (Executable::Fixed(_), DataType::Fixed) => {}
        _ => anyhow::bail!("plan dtype does not match executable representation"),
    }

    let outputs = match exe {
        Executable::Float(net) => net.run(input),
        Executable::Fixed(net) => net.run(input),
    };

    let acts = exe.activations();
    let breakdown = cost::network_cycles(plan, &acts, opts);
    let cycles = breakdown.total();
    let seconds = cycles / plan.target.freq_hz();
    let utilization = cost::utilization(plan, &acts);

    let active_mw = match plan.target {
        Target::WolfCluster { cores } => {
            power::WOLF_CLUSTER.active_mw(cores.clamp(1, 8), utilization)
        }
        t => t.active_mw(),
    };
    let energy_uj = power::energy_uj(seconds, active_mw);
    let e2e_seconds = seconds + plan.target.fixed_overhead_seconds();
    let e2e_energy_uj = energy_uj
        + power::energy_uj(
            plan.target.fixed_overhead_seconds(),
            plan.target.fixed_overhead_mw(),
        );

    Ok(SimReport {
        outputs,
        breakdown,
        seconds,
        active_mw,
        energy_uj,
        utilization,
        e2e_seconds,
        e2e_energy_uj,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::fann::Activation;
    use crate::targets::Chip;
    use crate::util::rng::Rng;

    fn float_net(sizes: &[usize]) -> Network {
        let mut rng = Rng::new(55);
        let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        net
    }

    #[test]
    fn outputs_identical_across_targets() {
        let net = float_net(&[7, 6, 5]);
        let shape = NetShape::from(&net);
        let x = [0.1f32, -0.5, 0.9, 0.0, 0.3, -0.2, 0.7];
        let mut outs = Vec::new();
        for t in [
            Target::CortexM4(Chip::Nrf52832),
            Target::WolfCluster { cores: 1 },
            Target::WolfCluster { cores: 8 },
        ] {
            let p = plan(&shape, t, DataType::Float32).unwrap();
            let r = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
            outs.push(r.outputs);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn fixed_deployment_runs_quantized_path() {
        let net = float_net(&[7, 6, 5]);
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let shape = NetShape::from(&fixed);
        let p = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let x = [0.1f32, -0.5, 0.9, 0.0, 0.3, -0.2, 0.7];
        let r = simulate(&p, &Executable::Fixed(&fixed), &x, CostOptions::default()).unwrap();
        // Outputs close to the float net's (quantization noise only).
        let rf = net.run(&x);
        for (a, b) in r.outputs.iter().zip(&rf) {
            assert!((a - b).abs() < 0.08, "{a} vs {b}");
        }
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let net = float_net(&[4, 3, 2]);
        let shape = NetShape::from(&net);
        let p = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let x = [0.0f32; 4];
        assert!(simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).is_err());
    }

    #[test]
    fn cluster_pays_e2e_overhead_once() {
        let net = float_net(&[76, 300, 200, 100, 10]);
        let shape = NetShape::from(&net);
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let x = vec![0.1f32; 76];
        let r = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
        assert!(r.e2e_seconds > r.seconds + 1.0e-3);
        // Amortization: at 1000 classifications the overhead vanishes.
        let amortized = r.amortized_seconds(p.target, 1000);
        assert!((amortized - r.seconds) < 2e-6);
    }

    #[test]
    fn table2_app_a_energy_shape() {
        // The headline: multi-RI5CY beats M4 by ~22x in time and ~73% in
        // energy for continuous classification (overhead amortized).
        let net = float_net(&[76, 300, 200, 100, 10]);
        let shape = NetShape::from(&net);
        let x = vec![0.1f32; 76];

        let p_m4 = plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let r_m4 = simulate(&p_m4, &Executable::Float(&net), &x, CostOptions::default()).unwrap();

        let p_w = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let r_w = simulate(&p_w, &Executable::Float(&net), &x, CostOptions::default()).unwrap();

        let speedup = r_m4.seconds / r_w.seconds;
        assert!(
            (17.0..=27.0).contains(&speedup),
            "modeled {speedup:.1}x, paper 22x"
        );
        let energy_red = 1.0 - r_w.energy_uj / r_m4.energy_uj;
        assert!(
            (0.60..=0.85).contains(&energy_red),
            "modeled {:.1}%, paper 73.1%",
            energy_red * 100.0
        );
    }

    #[test]
    fn nofit_plan_rejected() {
        let shape = NetShape::new(&[2048, 2048, 8]);
        let net = float_net(&[2048, 2048, 8]);
        let p = plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let x = vec![0.0f32; 2048];
        assert!(simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).is_err());
    }
}
