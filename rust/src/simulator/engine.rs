//! Deployment execution engine: runs a network *numerically* (float or
//! fixed path — bit-exact with the Pallas kernels) while accounting
//! cycles, time and energy from the [`super::cost`] model.
//!
//! Numeric outputs are target-independent (the same arithmetic runs on
//! every MCU); only the cycle/energy report varies with the plan — which
//! is exactly the paper's premise.

use anyhow::{ensure, Result};

use super::cost::{self, CostOptions, CycleBreakdown};
use crate::deploy::DeploymentPlan;
use crate::fann::activation::Activation;
use crate::fann::{FixedNetwork, Network};
use crate::kernels::{self, BatchScratch, ExecPlan, PlanScratch};
use crate::quantize;
use crate::targets::{power, DataType, Target};

/// Reusable scratch for batched [`Executable`] execution: the float and
/// Q-format ping-pong arenas plus the fixed path's quantize/dequantize
/// staging buffers and the compiled-plan flat scratch. Grown once,
/// reused for every batch of a stream — `apps::classify_stream_with`
/// threads one through a whole workload.
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// Float ping-pong arena.
    pub f: BatchScratch<f32>,
    /// Q-format ping-pong arena.
    pub q: BatchScratch<i32>,
    plan: PlanScratch,
    qin: Vec<i32>,
    qout: Vec<i32>,
}

impl ExecScratch {
    /// Empty scratch; every buffer grows on first use.
    pub fn new() -> Self {
        Self::default()
    }
}

/// The executable forms a deployment can carry. `Compiled` executes an
/// ahead-of-time [`ExecPlan`] (any representation) — same numerics as
/// the network it was compiled from, zero per-layer dispatch.
#[derive(Debug)]
pub enum Executable<'a> {
    /// The float reference network.
    Float(&'a Network),
    /// The wide Q(dec) network.
    Fixed(&'a FixedNetwork),
    /// An ahead-of-time compiled execution plan.
    Compiled(&'a ExecPlan),
}

impl<'a> Executable<'a> {
    /// Input width of the executable network.
    pub fn num_inputs(&self) -> usize {
        match self {
            Executable::Float(n) => n.num_inputs(),
            Executable::Fixed(n) => n.num_inputs(),
            Executable::Compiled(p) => p.num_inputs(),
        }
    }

    /// Output width of the executable network.
    pub fn num_outputs(&self) -> usize {
        match self {
            Executable::Float(n) => n.num_outputs(),
            Executable::Fixed(n) => n.num_outputs(),
            Executable::Compiled(p) => p.num_outputs(),
        }
    }

    /// Execute one sample numerically (float outputs; dequantized for
    /// fixed executables). All arms dispatch through the crate's
    /// kernel layer — `Compiled` through its frozen concrete kernels.
    pub fn forward(&self, input: &[f32]) -> Vec<f32> {
        match self {
            Executable::Float(n) => n.run(input),
            Executable::Fixed(n) => n.run(input),
            Executable::Compiled(p) => p.run(input),
        }
    }

    /// Execute `n_samples` packed rows through the batched kernels.
    /// Per-sample results are bit-identical to [`forward`](Self::forward).
    pub fn forward_batch(&self, inputs: &[f32], n_samples: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n_samples * self.num_outputs()];
        let mut scratch = ExecScratch::new();
        self.forward_batch_into(inputs, n_samples, &mut scratch, &mut out);
        out
    }

    /// [`forward_batch`](Self::forward_batch) with caller-owned scratch
    /// and output — the allocation-free steady-state form. For fixed
    /// executables, quantize → batched Q inference → dequantize all
    /// stage through `scratch`.
    pub fn forward_batch_into(
        &self,
        inputs: &[f32],
        n_samples: usize,
        scratch: &mut ExecScratch,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), n_samples * self.num_outputs());
        match self {
            Executable::Float(n) => {
                n.run_batch_into(kernels::default_f32(), inputs, n_samples, &mut scratch.f, out);
            }
            Executable::Fixed(n) => {
                scratch.qin.clear();
                scratch
                    .qin
                    .extend(inputs.iter().map(|&v| quantize::quantize(v, n.decimal_point)));
                scratch.qout.resize(out.len(), 0);
                n.run_batch_q_into(&scratch.qin, n_samples, &mut scratch.q, &mut scratch.qout[..]);
                for (o, &q) in out.iter_mut().zip(scratch.qout.iter()) {
                    *o = quantize::dequantize(q as i64, n.decimal_point);
                }
            }
            Executable::Compiled(p) => {
                if p.is_float() {
                    p.run_batch_f32_into(inputs, n_samples, &mut scratch.plan, out);
                } else {
                    let dec = p.decimal_point().expect("fixed plan has a decimal point");
                    scratch.qin.clear();
                    scratch.qin.extend(inputs.iter().map(|&v| quantize::quantize(v, dec)));
                    scratch.qout.resize(out.len(), 0);
                    p.run_batch_q_into(
                        &scratch.qin,
                        n_samples,
                        &mut scratch.plan,
                        &mut scratch.qout[..],
                    );
                    for (o, &q) in out.iter_mut().zip(scratch.qout.iter()) {
                        *o = quantize::dequantize(q as i64, dec);
                    }
                }
            }
        }
    }

    /// Per-layer activations, in order.
    pub fn activations(&self) -> Vec<Activation> {
        match self {
            Executable::Float(n) => n.layers.iter().map(|l| l.activation).collect(),
            Executable::Fixed(n) => n.layers.iter().map(|l| l.activation).collect(),
            Executable::Compiled(p) => p.activations(),
        }
    }

    /// Layer sizes `[in, h1, ..., out]`.
    pub fn layer_sizes(&self) -> Vec<usize> {
        match self {
            Executable::Float(n) => n.layer_sizes(),
            Executable::Fixed(n) => n.layer_sizes(),
            Executable::Compiled(p) => p.layer_sizes(),
        }
    }
}

/// Result of one simulated classification.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Network outputs (dequantized for fixed-point deployments).
    pub outputs: Vec<f32>,
    /// Cycle breakdown of the compute phase.
    pub breakdown: CycleBreakdown,
    /// Compute-phase wall time at the target's clock.
    pub seconds: f64,
    /// Average power during compute (utilization-aware for the cluster).
    pub active_mw: f64,
    /// Compute-phase energy.
    pub energy_uj: f64,
    /// Core-busy fraction (1.0 for single-core targets).
    pub utilization: f64,
    /// End-to-end time for ONE classification including the one-time
    /// cluster activation/deactivation overhead (Table II footnote).
    pub e2e_seconds: f64,
    /// End-to-end energy for one classification.
    pub e2e_energy_uj: f64,
}

impl SimReport {
    /// Amortized per-classification time when `n` classifications run per
    /// cluster activation (the paper's asymptotic 22× / 14.3× numbers).
    pub fn amortized_seconds(&self, plan_target: Target, n: u64) -> f64 {
        self.seconds + plan_target.fixed_overhead_seconds() / n as f64
    }

    /// Amortized per-classification energy for `n` classifications per
    /// activation.
    pub fn amortized_energy_uj(&self, plan_target: Target, n: u64) -> f64 {
        self.energy_uj
            + power::energy_uj(
                plan_target.fixed_overhead_seconds(),
                plan_target.fixed_overhead_mw(),
            ) / n as f64
    }
}

/// Plan/executable compatibility checks shared by [`simulate`] and
/// [`simulate_batch`].
fn validate(plan: &DeploymentPlan, exe: &Executable) -> Result<()> {
    ensure!(plan.fits(), "network does not fit {}", plan.target.label());
    ensure!(
        exe.layer_sizes() == plan.shape.sizes,
        "plan shape does not match executable"
    );
    match (&exe, plan.dtype) {
        (Executable::Float(_), DataType::Float32) | (Executable::Fixed(_), DataType::Fixed) => {}
        (Executable::Compiled(p), DataType::Float32) if p.is_float() => {}
        (Executable::Compiled(p), DataType::Fixed) if !p.is_float() => {}
        _ => anyhow::bail!("plan dtype does not match executable representation"),
    }
    Ok(())
}

/// The target-dependent cost of one classification under a plan — the
/// half of a [`SimReport`] that does not depend on the numerics (the
/// cost model is independent of them: the paper's premise). Shared by
/// the simulator, the deploy-plan builder ([`crate::codegen::plan`])
/// and the emulator ([`crate::emulator`]), so all three always quote
/// the same cycles/time/energy for the same plan.
#[derive(Debug, Clone)]
pub struct TargetCost {
    /// Cycle breakdown of the compute phase.
    pub breakdown: CycleBreakdown,
    /// Compute-phase wall time at the target clock.
    pub seconds: f64,
    /// Average power during compute (utilization-aware).
    pub active_mw: f64,
    /// Compute-phase energy in microjoules.
    pub energy_uj: f64,
    /// Cluster core-busy fraction (1.0 on single-core targets).
    pub utilization: f64,
    /// One-classification time incl. the one-time cluster bring-up.
    pub e2e_seconds: f64,
    /// One-classification energy incl. the bring-up phase.
    pub e2e_energy_uj: f64,
}

/// Evaluate the cycle/time/energy model for one classification under
/// `plan` with per-layer activations `acts`.
pub fn target_cost(plan: &DeploymentPlan, acts: &[Activation], opts: CostOptions) -> TargetCost {
    let breakdown = cost::network_cycles(plan, acts, opts);
    let cycles = breakdown.total();
    let seconds = cycles / plan.target.freq_hz();
    let utilization = cost::utilization(plan, acts, opts);

    let active_mw = match plan.target {
        Target::WolfCluster { cores } => {
            power::WOLF_CLUSTER.active_mw(cores.clamp(1, 8), utilization)
        }
        t => t.active_mw(),
    };
    let energy_uj = power::energy_uj(seconds, active_mw);
    let e2e_seconds = seconds + plan.target.fixed_overhead_seconds();
    let e2e_energy_uj = energy_uj
        + power::energy_uj(
            plan.target.fixed_overhead_seconds(),
            plan.target.fixed_overhead_mw(),
        );

    TargetCost {
        breakdown,
        seconds,
        active_mw,
        energy_uj,
        utilization,
        e2e_seconds,
        e2e_energy_uj,
    }
}

/// Build the cycle/time/energy report for one classification under
/// `plan`, attaching already-computed `outputs`.
fn cost_report(
    plan: &DeploymentPlan,
    exe: &Executable,
    outputs: Vec<f32>,
    opts: CostOptions,
) -> SimReport {
    let c = target_cost(plan, &exe.activations(), opts);
    SimReport {
        outputs,
        breakdown: c.breakdown,
        seconds: c.seconds,
        active_mw: c.active_mw,
        energy_uj: c.energy_uj,
        utilization: c.utilization,
        e2e_seconds: c.e2e_seconds,
        e2e_energy_uj: c.e2e_energy_uj,
    }
}

/// Simulate one classification of `input` under `plan`.
pub fn simulate(
    plan: &DeploymentPlan,
    exe: &Executable,
    input: &[f32],
    opts: CostOptions,
) -> Result<SimReport> {
    validate(plan, exe)?;
    ensure!(
        input.len() == exe.num_inputs(),
        "input length {} != network inputs {}",
        input.len(),
        exe.num_inputs()
    );
    let outputs = exe.forward(input);
    Ok(cost_report(plan, exe, outputs, opts))
}

/// Result of simulating a batch of classifications executed in one
/// activation window (the paper's continuous-classification operating
/// mode, where the cluster bring-up cost is paid once per stream, not
/// once per sample).
#[derive(Debug, Clone)]
pub struct BatchSimReport {
    /// All `n_samples × n_out` outputs, packed row-major — bit-identical
    /// to running each sample through [`simulate`] alone.
    pub outputs: Vec<f32>,
    /// Samples in the batch.
    pub n_samples: usize,
    /// The single-classification report the batch totals scale from
    /// (its `outputs` are the first sample's).
    pub per_sample: SimReport,
    /// Modeled time for the whole batch: `n · compute + one bring-up`.
    pub total_seconds: f64,
    /// Modeled energy for the whole batch.
    pub total_energy_uj: f64,
    /// Modeled sustained classification rate over the batch.
    pub throughput_hz: f64,
}

/// Simulate `n_samples` packed classifications under `plan`, paying the
/// target's fixed activation overhead once for the whole batch — the
/// execution-model counterpart of [`SimReport::amortized_seconds`].
pub fn simulate_batch(
    plan: &DeploymentPlan,
    exe: &Executable,
    inputs: &[f32],
    n_samples: usize,
    opts: CostOptions,
) -> Result<BatchSimReport> {
    let mut scratch = ExecScratch::new();
    simulate_batch_with(plan, exe, inputs, n_samples, opts, &mut scratch)
}

/// [`simulate_batch`] with caller-owned [`ExecScratch`]: repeated
/// batches of a stream reuse one arena instead of reallocating the
/// ping-pong buffers per call (only the report's output vector is
/// allocated).
pub fn simulate_batch_with(
    plan: &DeploymentPlan,
    exe: &Executable,
    inputs: &[f32],
    n_samples: usize,
    opts: CostOptions,
    scratch: &mut ExecScratch,
) -> Result<BatchSimReport> {
    ensure!(n_samples > 0, "batch must contain at least one sample");
    ensure!(
        inputs.len() == n_samples * exe.num_inputs(),
        "inputs length {} != {} samples x {} network inputs",
        inputs.len(),
        n_samples,
        exe.num_inputs()
    );
    validate(plan, exe)?;
    // One batched forward covers every sample (no redundant re-run of
    // sample 0); the per-sample report reuses its first row.
    let mut outputs = vec![0.0f32; n_samples * exe.num_outputs()];
    exe.forward_batch_into(inputs, n_samples, scratch, &mut outputs);
    let per_sample = cost_report(plan, exe, outputs[..exe.num_outputs()].to_vec(), opts);
    let n = n_samples as f64;
    let total_seconds = per_sample.seconds * n + plan.target.fixed_overhead_seconds();
    let total_energy_uj = per_sample.energy_uj * n
        + power::energy_uj(
            plan.target.fixed_overhead_seconds(),
            plan.target.fixed_overhead_mw(),
        );
    Ok(BatchSimReport {
        outputs,
        n_samples,
        per_sample,
        total_seconds,
        total_energy_uj,
        throughput_hz: n / total_seconds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::fann::Activation;
    use crate::targets::Chip;
    use crate::util::rng::Rng;

    fn float_net(sizes: &[usize]) -> Network {
        let mut rng = Rng::new(55);
        let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        net
    }

    #[test]
    fn outputs_identical_across_targets() {
        let net = float_net(&[7, 6, 5]);
        let shape = NetShape::from(&net);
        let x = [0.1f32, -0.5, 0.9, 0.0, 0.3, -0.2, 0.7];
        let mut outs = Vec::new();
        for t in [
            Target::CortexM4(Chip::Nrf52832),
            Target::WolfCluster { cores: 1 },
            Target::WolfCluster { cores: 8 },
        ] {
            let p = plan(&shape, t, DataType::Float32).unwrap();
            let r = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
            outs.push(r.outputs);
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn fixed_deployment_runs_quantized_path() {
        let net = float_net(&[7, 6, 5]);
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let shape = NetShape::from(&fixed);
        let p = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let x = [0.1f32, -0.5, 0.9, 0.0, 0.3, -0.2, 0.7];
        let r = simulate(&p, &Executable::Fixed(&fixed), &x, CostOptions::default()).unwrap();
        // Outputs close to the float net's (quantization noise only).
        let rf = net.run(&x);
        for (a, b) in r.outputs.iter().zip(&rf) {
            assert!((a - b).abs() < 0.08, "{a} vs {b}");
        }
    }

    #[test]
    fn batch_outputs_match_per_sample_and_amortize_overhead() {
        let net = float_net(&[7, 6, 5]);
        let shape = NetShape::from(&net);
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let mut rng = Rng::new(3);
        let n = 16;
        let xs: Vec<f32> = (0..n * 7).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let r =
            simulate_batch(&p, &Executable::Float(&net), &xs, n, CostOptions::default()).unwrap();
        assert_eq!(r.outputs.len(), n * 5);
        assert_eq!(r.n_samples, n);
        for s in 0..n {
            let single = simulate(
                &p,
                &Executable::Float(&net),
                &xs[s * 7..(s + 1) * 7],
                CostOptions::default(),
            )
            .unwrap();
            assert_eq!(&r.outputs[s * 5..(s + 1) * 5], &single.outputs[..], "sample {s}");
        }
        // The batch pays the cluster bring-up once, so it beats n
        // independent end-to-end classifications.
        assert!(r.total_seconds < n as f64 * r.per_sample.e2e_seconds);
        assert!(r.throughput_hz > 1.0 / r.per_sample.e2e_seconds);
        // Degenerate batches are rejected.
        assert!(
            simulate_batch(&p, &Executable::Float(&net), &[], 0, CostOptions::default()).is_err()
        );
    }

    #[test]
    fn compiled_executable_matches_interpreted_paths() {
        let net = float_net(&[7, 6, 5]);
        let shape = NetShape::from(&net);
        let x = [0.1f32, -0.5, 0.9, 0.0, 0.3, -0.2, 0.7];

        // Float plan vs float network, same deployment plan.
        let plan_f = net.compile_plan();
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let want = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
        let got = simulate(&p, &Executable::Compiled(&plan_f), &x, CostOptions::default()).unwrap();
        assert_eq!(got.outputs, want.outputs);
        assert_eq!(got.breakdown.total(), want.breakdown.total());

        // Fixed plan vs fixed network.
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let plan_q = fixed.compile_plan();
        let pq = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let want_q = simulate(&pq, &Executable::Fixed(&fixed), &x, CostOptions::default()).unwrap();
        let got_q =
            simulate(&pq, &Executable::Compiled(&plan_q), &x, CostOptions::default()).unwrap();
        assert_eq!(got_q.outputs, want_q.outputs);

        // Batched form through the shared scratch agrees per sample.
        let mut rng = Rng::new(8);
        let n = 9;
        let xs: Vec<f32> = (0..n * 7).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let rb = simulate_batch(&p, &Executable::Compiled(&plan_f), &xs, n, CostOptions::default())
            .unwrap();
        assert_eq!(rb.outputs, net.run_batch(&xs, n));

        // Representation mismatch is rejected for compiled plans too.
        assert!(simulate(&pq, &Executable::Compiled(&plan_f), &x, CostOptions::default()).is_err());
        assert!(simulate(&p, &Executable::Compiled(&plan_q), &x, CostOptions::default()).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let net = float_net(&[4, 3, 2]);
        let shape = NetShape::from(&net);
        let p = plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
        let x = [0.0f32; 4];
        assert!(simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).is_err());
    }

    #[test]
    fn cluster_pays_e2e_overhead_once() {
        let net = float_net(&[76, 300, 200, 100, 10]);
        let shape = NetShape::from(&net);
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let x = vec![0.1f32; 76];
        let r = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
        assert!(r.e2e_seconds > r.seconds + 1.0e-3);
        // Amortization: at 1000 classifications the overhead vanishes.
        let amortized = r.amortized_seconds(p.target, 1000);
        assert!((amortized - r.seconds) < 2e-6);
    }

    #[test]
    fn table2_app_a_energy_shape() {
        // The headline: multi-RI5CY beats M4 by ~22x in time and ~73% in
        // energy for continuous classification (overhead amortized).
        let net = float_net(&[76, 300, 200, 100, 10]);
        let shape = NetShape::from(&net);
        let x = vec![0.1f32; 76];

        let p_m4 = plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let r_m4 = simulate(&p_m4, &Executable::Float(&net), &x, CostOptions::default()).unwrap();

        let p_w = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let r_w = simulate(&p_w, &Executable::Float(&net), &x, CostOptions::default()).unwrap();

        let speedup = r_m4.seconds / r_w.seconds;
        assert!(
            (17.0..=27.0).contains(&speedup),
            "modeled {speedup:.1}x, paper 22x"
        );
        let energy_red = 1.0 - r_w.energy_uj / r_m4.energy_uj;
        assert!(
            (0.60..=0.85).contains(&energy_red),
            "modeled {:.1}%, paper 73.1%",
            energy_red * 100.0
        );
    }

    #[test]
    fn nofit_plan_rejected() {
        let shape = NetShape::new(&[2048, 2048, 8]);
        let net = float_net(&[2048, 2048, 8]);
        let p = plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let x = vec![0.0f32; 2048];
        assert!(simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).is_err());
    }
}
