//! Pure cycle-cost model: maps a [`DeploymentPlan`] to per-layer and
//! whole-network cycle counts. This is the analytical heart of every
//! figure reproduction (Figs. 7–12); the numeric execution engine
//! ([`super::engine`]) reuses it for timing while computing real outputs.
//!
//! Cost of one layer on `p` cores:
//!
//! ```text
//! rows_pc  = ceil(n_out / p)
//! row      = n_in · mac_eff + neuron_ovh + act + dma_row_setup?
//! layer    = layer_ovh + rows_pc · row · contention + barrier? + dma_layer?
//! ```
//!
//! where `mac_eff` folds the per-word memory penalty of the placement
//! region (flash wait states, shared-L2 arbitration) on top of the
//! Table I inner-loop cycles.

use crate::deploy::{DeploymentPlan, DmaStrategy};
use crate::fann::activation::Activation;
use crate::kernels::exec_plan::rows_per_core_block_max;
use crate::targets::{dma, memspec, Region, Target};

/// Synchronization cost per layer for a parallel cluster section
/// (fork + barrier through the event unit).
pub const BARRIER_CYCLES: f64 = 200.0;

/// Extra multiplicative compute cost per additional streaming core
/// (TCDM banking + DMA arbitration contention).
pub const STREAM_CONTENTION_PER_CORE: f64 = 0.008;

/// Per-neuron extra cycles of the *unoptimized* FANNCortexM baseline
/// (redundant bias-buffer initialization, Sec. V-B / Fig. 7), float and
/// fixed variants. Eliminated by FANN-on-MCU.
pub const LEGACY_INIT_FLOAT: f64 = 14.0;
/// Fixed-point variant of [`LEGACY_INIT_FLOAT`].
pub const LEGACY_INIT_FIXED: f64 = 31.0;

/// Cycle breakdown of a simulated inference.
#[derive(Debug, Clone, Copy, Default)]
pub struct CycleBreakdown {
    /// Inner-loop MAC cycles.
    pub compute: f64,
    /// Visible (un-hidden) DMA cycles.
    pub dma: f64,
    /// Cluster fork/barrier synchronization cycles.
    pub barrier: f64,
    /// Per-layer and per-neuron bookkeeping cycles.
    pub overhead: f64,
    /// Cycles spent in activation functions (Fig. 7 separates weight
    /// matrix vs activation time).
    pub activation: f64,
}

impl CycleBreakdown {
    /// Sum of every cycle category.
    pub fn total(&self) -> f64 {
        self.compute + self.dma + self.barrier + self.overhead + self.activation
    }

    fn add(&mut self, other: &CycleBreakdown) {
        self.compute += other.compute;
        self.dma += other.dma;
        self.barrier += other.barrier;
        self.overhead += other.overhead;
        self.activation += other.activation;
    }
}

/// Extra cycles per 32-bit weight load for the plan's placement region.
pub fn region_penalty_per_word(plan: &DeploymentPlan) -> f64 {
    match (plan.target, plan.region) {
        (
            Target::CortexM4(chip) | Target::CortexM7(chip) | Target::CortexM0(chip),
            Region::Flash,
        ) => {
            chip.memory().flash_penalty_per_word
        }
        (Target::WolfFc, Region::SharedL2) => memspec::WOLF_MEMORY.shared_l2_penalty_per_word,
        // Cluster L2-resident nets stream through the DMA: the per-word
        // cost is hidden, the DMA terms below carry the overhead.
        _ => 0.0,
    }
}

/// Simulation knobs (Fig. 7 legacy-baseline toggle + the packed-SIMD
/// MAC width of the emitted representation).
#[derive(Debug, Clone, Copy)]
pub struct CostOptions {
    /// Model the FANNCortexM redundant bias-init (the "before" bars).
    pub legacy_init: bool,
    /// MAC operands packed per inner-loop multiply (1 for f32/q32; the
    /// q7/q15 emitted representations set 2 or 4 on SIMD-capable cores
    /// — `pv.sdotsp` on RI5CY, `SMLAD` on the M4/M7 — mirroring the
    /// Fig. 3 `IsaExtensions::simd_lanes` ladder). Values < 1 are
    /// treated as 1.
    pub simd_lanes: u8,
    /// Row granularity of the parallel (neuron-wise) split: 1 for the
    /// row-granular f32/q32 kernels; the packed representations set 4
    /// because four output rows share one word panel, so a cluster
    /// core's work quantizes to whole panels
    /// ([`crate::kernels::exec_plan::split_row_blocks`] — the same
    /// partition the emulator walks and the host row-split driver
    /// executes). Values < 1 are treated as 1.
    pub row_block: u8,
}

impl Default for CostOptions {
    fn default() -> Self {
        Self {
            legacy_init: false,
            simd_lanes: 1,
            row_block: 1,
        }
    }
}

/// Cycles of one layer (`n_in -> n_out`, activation `act`) under `plan`.
/// `prev_compute` is the previous layer's compute time (layer-wise DMA
/// hides the next layer's transfer behind it); `first_layer` marks the
/// cold-start transfer.
pub fn layer_cycles(
    plan: &DeploymentPlan,
    n_in: usize,
    n_out: usize,
    act: Activation,
    prev_compute: f64,
    first_layer: bool,
    opts: CostOptions,
) -> CycleBreakdown {
    let core = plan.target.core();
    let cores = plan.target.num_cores() as usize;
    let lanes = opts.simd_lanes.max(1) as f64;
    let mac = core.mac_cycles(dtype_of(plan)) / lanes + region_penalty_per_word(plan);
    let word = crate::deploy::memory::dtype_size(plan.dtype);

    // Per-core rows of the crate's one row-split schedule
    // (`kernels::exec_plan::split_row_blocks` — the partition the host
    // row-split driver and the emulator actually walk): the wall-clock
    // rows of a parallel layer are whatever the fullest core received.
    // At row granularity (f32/q32) that equals ceil(n_out / cores);
    // packed reprs set `row_block = 4`, so small layers bill whole
    // word panels per core.
    let rows_pc = rows_per_core_block_max(n_out, opts.row_block.max(1) as usize, cores);
    let neuron_ovh = core.per_neuron_overhead()
        + if opts.legacy_init {
            match plan.dtype {
                crate::targets::DataType::Float32 => LEGACY_INIT_FLOAT,
                crate::targets::DataType::Fixed => LEGACY_INIT_FIXED,
            }
        } else {
            0.0
        };
    let act_cycles = core.activation_cycles(act);

    let mut b = CycleBreakdown::default();
    b.overhead = core.per_layer_overhead() + rows_pc as f64 * neuron_ovh;
    b.activation = rows_pc as f64 * act_cycles;
    b.compute = rows_pc as f64 * n_in as f64 * mac;

    // DMA streaming terms (cluster, L2-resident network).
    match plan.dma {
        Some(DmaStrategy::NeuronWise) => {
            let d = dma::WOLF_DMA;
            let row_bytes = n_in * word;
            let row_compute = n_in as f64 * mac;
            // Every layer's first row is cold (nothing to hide behind
            // after the barrier), then per-row programming with the
            // payload hidden behind the previous row's compute.
            let cold = d.transfer_cycles(row_bytes);
            b.dma = cold + (rows_pc.saturating_sub(1)) as f64 * d.overlapped_cost(row_bytes, row_compute);
        }
        Some(DmaStrategy::LayerWise) => {
            let d = dma::WOLF_DMA;
            let layer_bytes = (n_in * n_out + n_out) * word;
            b.dma = if first_layer {
                d.transfer_cycles(layer_bytes)
            } else {
                d.overlapped_cost(layer_bytes, prev_compute)
            };
        }
        None => {}
    }

    // Parallel-section costs.
    if cores > 1 {
        b.barrier = BARRIER_CYCLES;
        if plan.dma.is_some() {
            let contention = 1.0 + STREAM_CONTENTION_PER_CORE * (cores - 1) as f64;
            b.compute *= contention;
        }
    }
    b
}

/// Whole-network cycles under `plan`. `acts[l]` is the activation of
/// layer `l` (hidden/output mix resolved by the caller).
pub fn network_cycles(plan: &DeploymentPlan, acts: &[Activation], opts: CostOptions) -> CycleBreakdown {
    let sizes = &plan.shape.sizes;
    assert_eq!(acts.len(), sizes.len() - 1);
    let mut total = CycleBreakdown::default();
    let mut prev_compute = 0.0;
    for (l, w) in sizes.windows(2).enumerate() {
        let b = layer_cycles(plan, w[0], w[1], acts[l], prev_compute, l == 0, opts);
        prev_compute = b.compute;
        total.add(&b);
    }
    // Cluster runs additionally pay the input DMA into L1.
    if matches!(plan.target, Target::WolfCluster { .. }) {
        let word = crate::deploy::memory::dtype_size(plan.dtype);
        total.dma += dma::WOLF_DMA.transfer_cycles(sizes[0] * word);
    }
    total
}

/// Core-busy fraction of a parallel run (ceil losses at each layer,
/// panel-quantized for packed representations via `opts.row_block`):
/// used by the power model for idle-at-barrier clock gating.
pub fn utilization(plan: &DeploymentPlan, acts: &[Activation], opts: CostOptions) -> f64 {
    let cores = plan.target.num_cores() as usize;
    if cores == 1 {
        return 1.0;
    }
    let block = opts.row_block.max(1) as usize;
    let sizes = &plan.shape.sizes;
    let core = plan.target.core();
    let mac = core.mac_cycles(dtype_of(plan));
    let mut busy = 0.0;
    let mut wall = 0.0;
    for (l, w) in sizes.windows(2).enumerate() {
        let row = w[0] as f64 * mac
            + core.per_neuron_overhead()
            + core.activation_cycles(acts[l]);
        let rows_pc = rows_per_core_block_max(w[1], block, cores) as f64;
        busy += w[1] as f64 * row;
        wall += rows_pc * row * cores as f64;
    }
    (busy / wall).clamp(0.0, 1.0)
}

fn dtype_of(plan: &DeploymentPlan) -> crate::targets::DataType {
    plan.dtype
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::targets::{Chip, DataType};

    const TANH: Activation = Activation::Tanh;
    const SIG: Activation = Activation::Sigmoid;

    fn acts_for(n_layers: usize) -> Vec<Activation> {
        let mut v = vec![TANH; n_layers - 1];
        v.push(SIG);
        v
    }

    fn app_a() -> NetShape {
        NetShape::new(&[76, 300, 200, 100, 10])
    }

    #[test]
    fn app_a_m4_runtime_near_paper() {
        // Paper Table II: 17.6 ms on nRF52832 @64 MHz (float, flash).
        let p = plan(&app_a(), Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
        let cycles = network_cycles(&p, &acts_for(4), CostOptions::default()).total();
        let ms = cycles / 64.0e3;
        assert!(
            (15.0..=20.0).contains(&ms),
            "modeled {ms:.2} ms, paper 17.6 ms"
        );
    }

    #[test]
    fn app_a_ibex_runtime_near_paper() {
        // Paper: 11.4 ms on the FC @100 MHz (fixed, shared L2).
        let p = plan(&app_a(), Target::WolfFc, DataType::Fixed).unwrap();
        let cycles = network_cycles(&p, &acts_for(4), CostOptions::default()).total();
        let ms = cycles / 100.0e3;
        assert!(
            (10.0..=13.0).contains(&ms),
            "modeled {ms:.2} ms, paper 11.4 ms"
        );
    }

    #[test]
    fn app_a_single_riscy_near_paper() {
        // Paper: 5.7 ms single RI5CY @100 MHz (neuron-wise DMA).
        let p = plan(&app_a(), Target::WolfCluster { cores: 1 }, DataType::Float32).unwrap();
        let cycles = network_cycles(&p, &acts_for(4), CostOptions::default()).total();
        let ms = cycles / 100.0e3;
        assert!(
            (5.0..=6.5).contains(&ms),
            "modeled {ms:.2} ms, paper 5.7 ms"
        );
    }

    #[test]
    fn app_a_parallel_speedup_near_paper() {
        // Paper: 7.1x multi- vs single-RI5CY on app A.
        let acts = acts_for(4);
        let single = plan(&app_a(), Target::WolfCluster { cores: 1 }, DataType::Float32).unwrap();
        let multi = plan(&app_a(), Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
        let s = network_cycles(&single, &acts, CostOptions::default()).total();
        let m = network_cycles(&multi, &acts, CostOptions::default()).total();
        let speedup = s / m;
        assert!(
            (6.3..=8.0).contains(&speedup),
            "modeled {speedup:.2}x, paper 7.1x"
        );
    }

    #[test]
    fn tiny_net_parallel_speedup_lower() {
        // Fig. 12a: ~4.5x for a single 8-unit hidden layer (100 inputs,
        // 8 outputs) — parallelization overhead dominates small nets.
        let shape = NetShape::new(&[100, 8, 8]);
        let acts = acts_for(2);
        let single = plan(&shape, Target::WolfCluster { cores: 1 }, DataType::Fixed).unwrap();
        let multi = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Fixed).unwrap();
        let speedup = network_cycles(&single, &acts, CostOptions::default()).total()
            / network_cycles(&multi, &acts, CostOptions::default()).total();
        assert!(
            (3.5..=5.5).contains(&speedup),
            "modeled {speedup:.2}x, paper ~4.5x"
        );
    }

    #[test]
    fn legacy_init_slowdown_matches_fig7() {
        // Fig. 7: eliminating the redundant init gains 3.1% (float) and
        // 7.7% (fixed) on the 5-100-100-3 example network on the M4.
        let shape = NetShape::new(&[5, 100, 100, 3]);
        let acts = acts_for(3);
        for (dt, want) in [(DataType::Float32, 0.031), (DataType::Fixed, 0.077)] {
            let p = plan(&shape, Target::CortexM4(Chip::Stm32l475vg), dt).unwrap();
            let new = network_cycles(&p, &acts, CostOptions::default()).total();
            let old = network_cycles(
                &p,
                &acts,
                CostOptions {
                    legacy_init: true,
                    ..CostOptions::default()
                },
            )
            .total();
            let gain = (old - new) / old;
            assert!(
                (gain - want).abs() < 0.02,
                "{dt:?}: modeled gain {gain:.3}, paper {want}"
            );
        }
    }

    #[test]
    fn weight_matrix_dominates_example_net() {
        // Fig. 7: weight-matrix compute is ~88% of total on the example
        // network.
        let shape = NetShape::new(&[5, 100, 100, 3]);
        let p = plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
        let b = network_cycles(&p, &acts_for(3), CostOptions::default());
        let frac = b.compute / b.total();
        assert!((0.80..=0.95).contains(&frac), "compute fraction {frac:.3}");
    }

    #[test]
    fn simd_lanes_shrink_compute_only() {
        let p = plan(&app_a(), Target::WolfCluster { cores: 1 }, DataType::Fixed).unwrap();
        let acts = acts_for(4);
        let one = network_cycles(&p, &acts, CostOptions::default());
        let four = network_cycles(
            &p,
            &acts,
            CostOptions {
                simd_lanes: 4,
                ..CostOptions::default()
            },
        );
        assert!((four.compute - one.compute / 4.0).abs() < 1e-6);
        assert_eq!(four.overhead, one.overhead);
        assert_eq!(four.activation, one.activation);
        // simd_lanes: 0 is clamped to 1, never a divide-by-zero.
        let zero = network_cycles(
            &p,
            &acts,
            CostOptions {
                simd_lanes: 0,
                ..CostOptions::default()
            },
        );
        assert_eq!(zero.total(), one.total());
    }

    #[test]
    fn utilization_drops_for_tiny_layers() {
        let big = plan(&app_a(), Target::WolfCluster { cores: 8 }, DataType::Fixed).unwrap();
        let small = plan(
            &NetShape::new(&[100, 2, 2]),
            Target::WolfCluster { cores: 8 },
            DataType::Fixed,
        )
        .unwrap();
        let acts = acts_for(4);
        let u_big = utilization(&big, &acts, CostOptions::default());
        let u_small = utilization(&small, &acts_for(2), CostOptions::default());
        assert!(u_big > 0.85, "{u_big}");
        assert!(u_small < 0.5, "{u_small}");
    }

    #[test]
    fn packed_row_block_bills_whole_panels_on_the_cluster() {
        // 16 output rows on 8 cores: row-granular billing is 2 rows per
        // core, but a packed layer's 4 panels can only go to 4 cores —
        // the fullest core computes one whole panel (4 rows). The
        // row_block knob makes the estimate follow the panel schedule.
        let shape = NetShape::new(&[64, 16, 16]);
        let p = plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Fixed).unwrap();
        let acts = acts_for(2);
        let row = network_cycles(&p, &acts, CostOptions::default());
        let panel = network_cycles(
            &p,
            &acts,
            CostOptions {
                row_block: 4,
                ..CostOptions::default()
            },
        );
        assert!(
            panel.compute > row.compute * 1.5,
            "panel-quantized compute {} should roughly double row-granular {}",
            panel.compute,
            row.compute
        );
        // Utilization drops correspondingly (half the cores idle).
        let u_row = utilization(&p, &acts, CostOptions::default());
        let u_panel = utilization(
            &p,
            &acts,
            CostOptions {
                row_block: 4,
                ..CostOptions::default()
            },
        );
        assert!(u_panel < u_row, "{u_panel} vs {u_row}");
        // Single-core runs are unaffected by the block size.
        let p1 = plan(&shape, Target::WolfCluster { cores: 1 }, DataType::Fixed).unwrap();
        let a = network_cycles(&p1, &acts, CostOptions::default()).total();
        let b = network_cycles(
            &p1,
            &acts,
            CostOptions {
                row_block: 4,
                ..CostOptions::default()
            },
        )
        .total();
        assert_eq!(a, b);
    }

    #[test]
    fn fixed_faster_than_float_on_m4() {
        // Fig. 7: fixed ~15% faster than float on the M4.
        let shape = NetShape::new(&[5, 100, 100, 3]);
        let acts = acts_for(3);
        let pf = plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
        let pq = plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Fixed).unwrap();
        let f = network_cycles(&pf, &acts, CostOptions::default()).total();
        let q = network_cycles(&pq, &acts, CostOptions::default()).total();
        let gain = (f - q) / f;
        assert!((0.08..=0.2).contains(&gain), "fixed gain {gain:.3}");
    }
}
