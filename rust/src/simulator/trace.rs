//! Power-trace generation — the Fig. 13 reproduction.
//!
//! An end-to-end cluster classification decomposes into phases:
//! FC idle → cluster activation/init → input DMA → parallel compute →
//! cluster deactivation → FC idle. Each phase holds a constant average
//! power; the trace is the step function the Keysight N6705C saw.

use crate::simulator::engine::SimReport;
use crate::targets::{power, Target};

/// One constant-power phase.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Phase label (`activation`, `compute`, ...).
    pub name: &'static str,
    /// Phase duration.
    pub seconds: f64,
    /// Average power during the phase.
    pub milliwatts: f64,
}

/// A full classification trace.
#[derive(Debug, Clone)]
pub struct PowerTrace {
    /// Phases in chronological order.
    pub phases: Vec<Phase>,
}

impl PowerTrace {
    /// Build the Fig. 13 trace from a simulated cluster run. The
    /// activation/deactivation split of the 1.2 ms bring-up overhead is
    /// 60/40 (activation + init is the longer leg).
    pub fn for_cluster_run(report: &SimReport, target: Target) -> Self {
        let overhead = target.fixed_overhead_seconds();
        let oh_mw = target.fixed_overhead_mw();
        let fc_idle = power::WOLF_FC.sleep_mw;
        let phases = vec![
            Phase {
                name: "idle",
                seconds: 0.2e-3,
                milliwatts: fc_idle,
            },
            Phase {
                name: "cluster activation + init",
                seconds: overhead * 0.6,
                milliwatts: oh_mw,
            },
            Phase {
                name: "input DMA",
                seconds: 5.0e-6,
                milliwatts: oh_mw,
            },
            Phase {
                name: "parallel compute",
                seconds: report.seconds,
                milliwatts: report.active_mw,
            },
            Phase {
                name: "cluster deactivation",
                seconds: overhead * 0.4,
                milliwatts: oh_mw,
            },
            Phase {
                name: "idle",
                seconds: 0.2e-3,
                milliwatts: fc_idle,
            },
        ];
        Self { phases }
    }

    /// Total duration across all phases.
    pub fn total_seconds(&self) -> f64 {
        self.phases.iter().map(|p| p.seconds).sum()
    }

    /// Total energy in µJ.
    pub fn total_energy_uj(&self) -> f64 {
        self.phases
            .iter()
            .map(|p| power::energy_uj(p.seconds, p.milliwatts))
            .sum()
    }

    /// Sample the step function at `n` evenly spaced points — the series
    /// a plotting tool (or the Fig. 13 bench output) consumes.
    pub fn sample(&self, n: usize) -> Vec<(f64, f64)> {
        let total = self.total_seconds();
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let t = total * i as f64 / (n - 1).max(1) as f64;
            out.push((t, self.power_at(t)));
        }
        out
    }

    /// Power at absolute time `t` within the trace.
    pub fn power_at(&self, t: f64) -> f64 {
        let mut acc = 0.0;
        for p in &self.phases {
            acc += p.seconds;
            if t < acc {
                return p.milliwatts;
            }
        }
        self.phases.last().map(|p| p.milliwatts).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{plan, NetShape};
    use crate::fann::{Activation, Network};
    use crate::simulator::cost::CostOptions;
    use crate::simulator::engine::{simulate, Executable};
    use crate::targets::DataType;
    use crate::util::rng::Rng;

    fn app_a_trace() -> PowerTrace {
        let mut rng = Rng::new(1);
        let mut net = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        net.randomize(&mut rng, None);
        let shape = NetShape::from(&net);
        let target = Target::WolfCluster { cores: 8 };
        let p = plan(&shape, target, DataType::Float32).unwrap();
        let x = vec![0.2f32; 76];
        let r = simulate(&p, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
        PowerTrace::for_cluster_run(&r, target)
    }

    #[test]
    fn fig13_phase_structure() {
        let trace = app_a_trace();
        let names: Vec<&str> = trace.phases.iter().map(|p| p.name).collect();
        assert_eq!(names[0], "idle");
        assert!(names.contains(&"cluster activation + init"));
        assert!(names.contains(&"parallel compute"));
        // Compute is the power peak (Fig. 13's tall plateau).
        let peak = trace
            .phases
            .iter()
            .max_by(|a, b| a.milliwatts.partial_cmp(&b.milliwatts).unwrap())
            .unwrap();
        assert_eq!(peak.name, "parallel compute");
        assert!(peak.milliwatts > 50.0, "{}", peak.milliwatts);
    }

    #[test]
    fn fig13_overhead_energy_near_13uj() {
        // Paper: constant overhead ≈ 13 µJ.
        let trace = app_a_trace();
        let oh: f64 = trace
            .phases
            .iter()
            .filter(|p| p.name.starts_with("cluster"))
            .map(|p| crate::targets::power::energy_uj(p.seconds, p.milliwatts))
            .sum();
        assert!((11.0..=16.0).contains(&oh), "{oh}");
    }

    #[test]
    fn sample_is_monotone_in_time() {
        let trace = app_a_trace();
        let samples = trace.sample(256);
        assert_eq!(samples.len(), 256);
        for w in samples.windows(2) {
            assert!(w[1].0 >= w[0].0);
        }
        // Starts and ends idle (sub-mW).
        assert!(samples.first().unwrap().1 < 1.0);
        assert!(samples.last().unwrap().1 < 1.0);
    }

    #[test]
    fn total_energy_consistent_with_phases() {
        let trace = app_a_trace();
        let total = trace.total_energy_uj();
        assert!(total > 0.0);
        // Dominated by compute + overhead; idle contributes ~nothing.
        let compute: f64 = trace
            .phases
            .iter()
            .filter(|p| p.name == "parallel compute")
            .map(|p| crate::targets::power::energy_uj(p.seconds, p.milliwatts))
            .sum();
        assert!(compute / total > 0.5, "compute {compute} total {total}");
    }
}
