//! Continuous real-time classification analysis — the operating mode
//! behind the paper's headline claim ("for continuous real-time
//! classification" the parallel implementation wins 22× / −69 %) and the
//! Eq. (2) double buffer ("considering the eventual double buffering for
//! continuous data processing from sensors").
//!
//! Given a simulated deployment and a sensor window rate, this module
//! answers: does the deployment keep up, what duty cycle does it run at,
//! and what average power / energy-per-window does continuous operation
//! cost — including whether it is worth keeping the cluster powered
//! between windows or duty-cycling it.

use crate::simulator::engine::SimReport;
use crate::targets::{power, Target};

/// How the cluster is managed between windows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// Activate/deactivate around every window (pays the 1.2 ms
    /// bring-up per window, sleeps between).
    DutyCycled,
    /// Keep the cluster powered across windows (no per-window overhead;
    /// idle cores burn the cluster base power between windows).
    AlwaysOn,
}

/// Result of a continuous-stream feasibility/energy analysis.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// Can the deployment classify every window at this rate?
    pub feasible: bool,
    /// Highest sustainable window rate (Hz).
    pub max_rate_hz: f64,
    /// Fraction of each period spent computing.
    pub duty_cycle: f64,
    /// Average power over a period (mW).
    pub avg_power_mw: f64,
    /// Energy per window (µJ), everything included.
    pub energy_per_window_uj: f64,
    /// The cluster policy this report describes (None for single-core
    /// targets).
    pub policy: Option<ClusterPolicy>,
}

/// Analyze continuous classification at `rate_hz` sensor windows/s.
///
/// For cluster targets, pass the desired [`ClusterPolicy`]; for
/// single-core targets the policy is ignored (they duty-cycle into
/// sleep implicitly).
pub fn analyze(
    report: &SimReport,
    target: Target,
    sleep_mw: f64,
    rate_hz: f64,
    policy: ClusterPolicy,
) -> StreamReport {
    let period = 1.0 / rate_hz;
    let is_cluster = matches!(target, Target::WolfCluster { .. });

    let (busy, busy_mw, idle_mw, pol) = if is_cluster {
        match policy {
            ClusterPolicy::DutyCycled => {
                // Window cost includes activation; idle is deep sleep.
                let busy = report.seconds + target.fixed_overhead_seconds();
                // Average power across compute + overhead phases.
                let e = report.energy_uj
                    + power::energy_uj(
                        target.fixed_overhead_seconds(),
                        target.fixed_overhead_mw(),
                    );
                let mw = e / busy * 1e-3;
                (busy, mw, sleep_mw, Some(ClusterPolicy::DutyCycled))
            }
            ClusterPolicy::AlwaysOn => {
                // No per-window overhead; idle burns cluster base power.
                (
                    report.seconds,
                    report.active_mw,
                    power::WOLF_CLUSTER.base_mw,
                    Some(ClusterPolicy::AlwaysOn),
                )
            }
        }
    } else {
        (report.seconds, report.active_mw, sleep_mw, None)
    };

    let feasible = busy <= period;
    let duty = (busy / period).min(1.0);
    let avg_mw = duty * busy_mw + (1.0 - duty) * idle_mw;
    let energy_per_window = power::energy_uj(busy, busy_mw)
        + power::energy_uj((period - busy).max(0.0), idle_mw);

    StreamReport {
        feasible,
        max_rate_hz: 1.0 / busy,
        duty_cycle: duty,
        avg_power_mw: avg_mw,
        energy_per_window_uj: energy_per_window,
        policy: pol,
    }
}

/// Pick the cheaper cluster policy at this rate (the crossover the
/// paper's break-even discussion implies: sparse windows favor
/// duty-cycling, dense windows favor keeping the cluster on).
pub fn best_cluster_policy(
    report: &SimReport,
    target: Target,
    sleep_mw: f64,
    rate_hz: f64,
) -> (ClusterPolicy, StreamReport) {
    let duty = analyze(report, target, sleep_mw, rate_hz, ClusterPolicy::DutyCycled);
    let always = analyze(report, target, sleep_mw, rate_hz, ClusterPolicy::AlwaysOn);
    match (duty.feasible, always.feasible) {
        (true, false) => (ClusterPolicy::DutyCycled, duty),
        (false, true) => (ClusterPolicy::AlwaysOn, always),
        _ => {
            if duty.energy_per_window_uj <= always.energy_per_window_uj {
                (ClusterPolicy::DutyCycled, duty)
            } else {
                (ClusterPolicy::AlwaysOn, always)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deploy::{self, NetShape};
    use crate::fann::{Activation, Network};
    use crate::simulator::{self, CostOptions, Executable};
    use crate::targets::{Chip, DataType};
    use crate::util::rng::Rng;

    fn report_for(target: Target) -> SimReport {
        let mut rng = Rng::new(41);
        let mut net = Network::new(
            &[76, 300, 200, 100, 10],
            Activation::Tanh,
            Activation::Sigmoid,
        )
        .unwrap();
        net.randomize(&mut rng, None);
        let plan = deploy::plan(&NetShape::from(&net), target, DataType::Float32).unwrap();
        let x = vec![0.1f32; 76];
        simulator::simulate(&plan, &Executable::Float(&net), &x, CostOptions::default()).unwrap()
    }

    #[test]
    fn m4_infeasible_above_its_rate() {
        let t = Target::CortexM4(Chip::Nrf52832);
        let r = report_for(t);
        // app A on the M4 takes ~17 ms -> ~58 Hz max.
        let ok = analyze(&r, t, 0.006, 10.0, ClusterPolicy::DutyCycled);
        assert!(ok.feasible);
        let too_fast = analyze(&r, t, 0.006, 100.0, ClusterPolicy::DutyCycled);
        assert!(!too_fast.feasible);
        assert!((50.0..70.0).contains(&too_fast.max_rate_hz));
    }

    #[test]
    fn cluster_always_on_sustains_higher_rates() {
        let t = Target::WolfCluster { cores: 8 };
        let r = report_for(t);
        // Duty-cycled: ~2 ms/window (1.2 ms activation) -> < 500 Hz.
        let duty = analyze(&r, t, 0.007, 400.0, ClusterPolicy::DutyCycled);
        // Always-on: ~0.75 ms/window -> > 1 kHz.
        let always = analyze(&r, t, 0.007, 400.0, ClusterPolicy::AlwaysOn);
        assert!(always.max_rate_hz > duty.max_rate_hz * 2.0);
        assert!(always.feasible);
    }

    #[test]
    fn policy_crossover_with_rate() {
        let t = Target::WolfCluster { cores: 8 };
        let r = report_for(t);
        // Sparse windows: duty-cycling wins (sleep between).
        let (p_slow, _) = best_cluster_policy(&r, t, 0.007, 0.5);
        assert_eq!(p_slow, ClusterPolicy::DutyCycled);
        // Dense windows: keeping the cluster on wins (no 1.2 ms tax).
        let (p_fast, rep) = best_cluster_policy(&r, t, 0.007, 600.0);
        assert_eq!(p_fast, ClusterPolicy::AlwaysOn);
        assert!(rep.feasible);
    }

    #[test]
    fn duty_cycle_and_power_bounds() {
        let t = Target::WolfCluster { cores: 8 };
        let r = report_for(t);
        let rep = analyze(&r, t, 0.007, 100.0, ClusterPolicy::AlwaysOn);
        assert!((0.0..=1.0).contains(&rep.duty_cycle));
        // Average power between idle base and full active.
        assert!(rep.avg_power_mw >= power::WOLF_CLUSTER.base_mw - 1e-9);
        assert!(rep.avg_power_mw <= r.active_mw + 1e-9);
    }

    #[test]
    fn headline_continuous_comparison() {
        // The paper's continuous-mode claim: at a rate both can sustain,
        // the 8-core cluster beats the M4 in energy per window.
        let m4 = Target::CortexM4(Chip::Nrf52832);
        let wolf = Target::WolfCluster { cores: 8 };
        let r_m4 = report_for(m4);
        let r_w = report_for(wolf);
        let rate = 20.0;
        let s_m4 = analyze(&r_m4, m4, 0.006, rate, ClusterPolicy::DutyCycled);
        let (_, s_w) = best_cluster_policy(&r_w, wolf, 0.007, rate);
        assert!(s_m4.feasible && s_w.feasible);
        assert!(
            s_w.energy_per_window_uj < s_m4.energy_per_window_uj,
            "wolf {} vs m4 {}",
            s_w.energy_per_window_uj,
            s_m4.energy_per_window_uj
        );
    }
}
