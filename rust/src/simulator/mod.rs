//! Deployment execution simulator.
//!
//! [`cost`] is the analytical cycle model (Table I inner loops + memory
//! penalties + DMA overlap + parallel overheads); [`engine`] executes a
//! deployed network numerically while accounting cycles/time/energy;
//! [`trace`] renders Fig.-13-style power traces of end-to-end cluster
//! classifications.

pub mod cost;
pub mod engine;
pub mod stream;
pub mod trace;

pub use cost::{network_cycles, CostOptions, CycleBreakdown};
pub use engine::{
    simulate, simulate_batch, simulate_batch_with, target_cost, BatchSimReport, ExecScratch,
    Executable, SimReport, TargetCost,
};
pub use stream::{analyze as analyze_stream, ClusterPolicy, StreamReport};
pub use trace::PowerTrace;
