//! Q-format fixed-point arithmetic — the FPU-less inference path.
//!
//! Semantics are FANN's (`fann_mult` et al.), shared across languages
//! and pinned together by parity tests:
//!
//! * `python/compile/kernels/ref.py` (numpy oracle),
//! * `python/compile/kernels/fixedpoint.py` (Pallas kernel),
//! * this crate — where the *primitives* (`quantize`/`qmul`/`sat_i32`
//!   and the step-linear activation tables) live here, and the dense
//!   inner loop lives once per strategy in [`crate::kernels`]:
//!   [`crate::kernels::FixedQ`] for wide i32 parameters and the packed
//!   [`crate::kernels::PackedQ7`]/[`crate::kernels::PackedQ15`] pair
//!   for word-packed narrow weights — all three reproduce exactly the
//!   per-product `qmul` + i64-accumulate + `sat_i32` semantics defined
//!   here, which is what makes them interchangeable bit for bit
//!   (`rust/tests/parity_packed.rs`).
//!
//! A value `v` is stored as `round(v * 2^dec)` in an `i32`; `dec` (the
//! *decimal point*) is network-wide, chosen by [`choose_decimal_point`].
//! Multiplications widen to `i64`, shift right arithmetically by `dec`
//! per product, accumulate in `i64`, and saturate to `i32` before the
//! step-linear activation (Table I right column: `mul / sra / add`).

use crate::fann::activation::Activation;

/// `i32::MIN` widened for saturation arithmetic.
pub const I32_MIN: i64 = i32::MIN as i64;
/// `i32::MAX` widened for saturation arithmetic.
pub const I32_MAX: i64 = i32::MAX as i64;

/// Saturate an `i64` accumulator to the `i32` range.
#[inline]
pub fn sat_i32(x: i64) -> i64 {
    x.clamp(I32_MIN, I32_MAX)
}

/// FANN's `fann_mult`: widen, multiply, arithmetic shift right by `dec`.
#[inline]
pub fn qmul(a: i32, b: i32, dec: u32) -> i64 {
    ((a as i64) * (b as i64)) >> dec
}

/// Quantize a float to Q(dec) with round-to-nearest, saturating.
#[inline]
pub fn quantize(v: f32, dec: u32) -> i32 {
    let scaled = (v as f64) * (1i64 << dec) as f64;
    sat_i32(scaled.round() as i64) as i32
}

/// Dequantize Q(dec) back to float.
#[inline]
pub fn dequantize(q: i64, dec: u32) -> f32 {
    (q as f64 / (1i64 << dec) as f64) as f32
}

/// Integer piecewise-linear interpolation over a breakpoint table,
/// mirroring `ref.py::_interp_table_q` (floor semantics; numerators are
/// non-negative inside segments so trunc == floor).
fn interp_table_q(x: i64, xs: &[i64], vs: &[i64], lo: i64, hi: i64) -> i64 {
    if x <= xs[0] {
        return lo;
    }
    if x >= xs[xs.len() - 1] {
        return hi;
    }
    // Find the segment: xs is small (<= 9 entries), linear scan is fine
    // and matches the MCU's compare-chain implementation.
    for i in 0..xs.len() - 1 {
        if x == xs[i] {
            // Interior breakpoint hit exactly.
            return vs[i];
        }
        if x > xs[i] && x < xs[i + 1] {
            let dxs = xs[i + 1] - xs[i];
            let dvs = vs[i + 1] - vs[i];
            return vs[i] + (x - xs[i]) * dvs / dxs;
        }
    }
    // x == last interior breakpoint.
    vs[xs.len() - 2]
}

/// Sigmoid breakpoint table in Q(dec) (matches ref.py `_sigmoid_table`).
fn sigmoid_table(dec: u32) -> ([i64; 9], [i64; 9]) {
    let one = 1i64 << dec;
    let pts: [i64; 9] = [-6, -4, -2, -1, 0, 1, 2, 4, 6];
    let mut xs = [0i64; 9];
    let mut vs = [0i64; 9];
    for i in 0..9 {
        xs[i] = pts[i] * one;
        let v = 1.0 / (1.0 + (-(pts[i] as f64)).exp());
        vs[i] = (v * one as f64).round() as i64;
    }
    (xs, vs)
}

/// Tanh breakpoint table in Q(dec) (matches ref.py `_tanh_table`).
fn tanh_table(dec: u32) -> ([i64; 7], [i64; 7]) {
    let one = 1i64 << dec;
    let pts: [i64; 7] = [-3, -2, -1, 0, 1, 2, 3];
    let mut xs = [0i64; 7];
    let mut vs = [0i64; 7];
    for i in 0..7 {
        xs[i] = pts[i] * one;
        vs[i] = ((pts[i] as f64).tanh() * one as f64).round() as i64;
    }
    (xs, vs)
}

/// FANN's step-linear sigmoid approximation in Q(dec).
pub fn step_linear_sigmoid_q(x: i64, dec: u32) -> i64 {
    let one = 1i64 << dec;
    let (xs, vs) = sigmoid_table(dec);
    interp_table_q(x, &xs, &vs, 0, one)
}

/// Symmetric step-linear sigmoid (tanh) in Q(dec).
pub fn step_linear_tanh_q(x: i64, dec: u32) -> i64 {
    let one = 1i64 << dec;
    let (xs, vs) = tanh_table(dec);
    interp_table_q(x, &xs, &vs, -one, one)
}

/// Fixed-point activation dispatch (saturating to i32 on return).
pub fn activation_q(act: Activation, x: i64, dec: u32) -> i64 {
    let y = match act {
        Activation::Linear => x,
        Activation::Relu => x.max(0),
        Activation::Sigmoid => step_linear_sigmoid_q(x, dec),
        Activation::Tanh => step_linear_tanh_q(x, dec),
    };
    sat_i32(y)
}

/// Fixed-point dense layer: `x_q` (n_in), row-major `w_q` ([n_out][n_in]),
/// `b_q` (n_out) -> writes n_out outputs. The exact math of
/// `ref.py::dense_q` (which uses column-major (In, Out); transposed here
/// to the MCU's neuron-row layout). The inner loop lives in
/// [`crate::kernels::FixedQ`]; this wrapper adds the step-linear
/// activation on top of the kernel's saturated pre-activation.
pub fn dense_q_into(
    x_q: &[i32],
    w_q: &[i32],
    b_q: &[i32],
    dec: u32,
    act: Activation,
    out: &mut [i32],
) {
    use crate::kernels::{DenseKernel, DenseLayerRef, FixedQ};
    let n_in = x_q.len();
    let n_out = b_q.len();
    debug_assert_eq!(w_q.len(), n_in * n_out);
    debug_assert_eq!(out.len(), n_out);
    let layer = DenseLayerRef::new(n_in, n_out, w_q, b_q);
    FixedQ::new(dec).matvec(&layer, x_q, out);
    for v in out.iter_mut() {
        *v = activation_q(act, *v as i64, dec) as i32;
    }
}

/// Decimal-point selection, following `fann_save_to_fixed`'s reasoning:
/// the decimal point must be small enough that (a) the largest weight is
/// representable in i32 and (b) a worst-case layer accumulation
/// (`max|w| · max|x| · fan_in` products plus bias) cannot overflow the
/// saturating i64->i32 clamp *in normal operation*.
///
/// `max_abs_w` — largest |weight| or |bias| in the net; `max_fan_in` —
/// widest layer input; `max_abs_x` — bound on layer inputs/activations
/// (1.0 for sigmoid/tanh nets with normalized inputs).
pub fn choose_decimal_point(max_abs_w: f32, max_fan_in: usize, max_abs_x: f32) -> u32 {
    // bits needed for the integer part of the worst-case accumulator:
    // fan_in * max|w| * max|x| (products are Q(dec) after the shift).
    let worst = (max_fan_in as f64) * (max_abs_w.max(1e-9) as f64) * (max_abs_x.max(1e-9) as f64);
    let int_bits = worst.log2().ceil().max(0.0) as u32;
    // 31 magnitude bits total; keep one guard bit.
    let avail = 31u32.saturating_sub(int_bits + 1);
    avail.clamp(1, 20)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_small_values() {
        let dec = 12;
        for v in [-1.5f32, -0.013, 0.0, 0.5, 1.9999] {
            let q = quantize(v, dec);
            let back = dequantize(q as i64, dec);
            assert!((v - back).abs() <= 1.0 / (1 << dec) as f32);
        }
    }

    #[test]
    fn qmul_matches_float_within_lsb() {
        let dec = 12;
        let a = quantize(1.25, dec);
        let b = quantize(-0.75, dec);
        let p = dequantize(qmul(a, b, dec), dec);
        assert!((p - (1.25 * -0.75)).abs() < 2.0 / (1 << dec) as f32);
    }

    #[test]
    fn sigmoid_q_fixed_points() {
        let dec = 12;
        let one = 1i64 << dec;
        assert_eq!(step_linear_sigmoid_q(0, dec), one / 2);
        assert_eq!(step_linear_sigmoid_q(-100 * one, dec), 0);
        assert_eq!(step_linear_sigmoid_q(100 * one, dec), one);
    }

    #[test]
    fn tanh_q_odd_within_lsb() {
        let dec = 10;
        let one = 1i64 << dec;
        for x in (-4 * one..4 * one).step_by(97) {
            let s = step_linear_tanh_q(x, dec) + step_linear_tanh_q(-x, dec);
            assert!(s.abs() <= 1, "x={x} s={s}");
        }
    }

    #[test]
    fn sigmoid_q_monotone() {
        let dec = 8;
        let one = 1i64 << dec;
        let mut prev = i64::MIN;
        for x in (-8 * one..8 * one).step_by(13) {
            let y = step_linear_sigmoid_q(x, dec);
            assert!(y >= prev);
            prev = y;
        }
    }

    #[test]
    fn dense_q_saturates_not_wraps() {
        let dec = 4;
        let one = 1i32 << dec;
        let n = 64;
        let x = vec![100_000 * one; n];
        let w = vec![100_000 * one; n];
        let b = vec![0i32];
        let mut out = vec![0i32; 1];
        dense_q_into(&x, &w, &b, dec, Activation::Linear, &mut out);
        assert_eq!(out[0] as i64, I32_MAX);
    }

    #[test]
    fn decimal_point_reasonable_for_typical_net() {
        // |w| <= 2, fan-in 300, |x| <= 1 -> worst ~ 600 -> 10 int bits.
        let dec = choose_decimal_point(2.0, 300, 1.0);
        assert!((10..=20).contains(&dec), "dec={dec}");
        // Huge weights squeeze the decimal point down.
        assert!(choose_decimal_point(1000.0, 1000, 1.0) < dec);
        // Bounds respected.
        assert!(choose_decimal_point(1e9, 10_000, 1.0) >= 1);
        assert!(choose_decimal_point(1e-9, 1, 1e-9) <= 20);
    }

    #[test]
    fn quantized_dense_tracks_float_dense() {
        use crate::util::rng::Rng;
        let dec = 12;
        let mut rng = Rng::new(21);
        let n_in = 20;
        let n_out = 7;
        let x: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range_f32(-1.5, 1.5)).collect();
        let b: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-0.5, 0.5)).collect();

        let xq: Vec<i32> = x.iter().map(|&v| quantize(v, dec)).collect();
        let wq: Vec<i32> = w.iter().map(|&v| quantize(v, dec)).collect();
        let bq: Vec<i32> = b.iter().map(|&v| quantize(v, dec)).collect();
        let mut outq = vec![0i32; n_out];
        dense_q_into(&xq, &wq, &bq, dec, Activation::Tanh, &mut outq);

        for o in 0..n_out {
            let mut acc = b[o];
            for i in 0..n_in {
                acc += w[o * n_in + i] * x[i];
            }
            let want = acc.tanh();
            let got = dequantize(outq[o] as i64, dec);
            // step-linear tanh approximation error dominates (the coarse
            // integer breakpoint table is off by up to ~4% mid-segment);
            // the paper tolerates it on MCUs, we tolerate 6% here.
            assert!(
                (want - got).abs() < 0.06,
                "o={o} want {want} got {got}"
            );
        }
    }
}
