//! Signal-level synthetic generators for the paper-reproduction suite
//! (the three wearable-bracelet case studies `paper reproduce` runs).
//!
//! Unlike the feature-space Gaussian clusters of [`super::generate`],
//! these model the *measurement* each wearable produces — an 8-channel
//! surface-EMG window, a single-lead ECG beat, per-band EEG log-powers
//! — and derive the classifier inputs from the synthesized signal, so
//! the class structure enters through physiologically-shaped parameters
//! (muscle synergies, QRS morphology, µ-rhythm desynchronization)
//! rather than through cluster means. Everything is deterministic per
//! seed (one [`Rng`] stream, forked per class where the class identity
//! must not depend on draw order) and class-balanced by construction.
//!
//! The real recordings behind the paper's case studies are not
//! redistributable; runtime, memory and energy depend only on topology,
//! and the accuracy targets only need to land in the published band, so
//! a shaped synthetic substitute preserves every quantity the
//! reproduction measures (DESIGN.md §1 records the same substitution
//! for the Sec. VI showcases).

use crate::fann::TrainData;
use crate::util::rng::Rng;

/// Samples per EMG window per channel (≈75 ms at 320 Hz envelope rate).
pub const EMG_WINDOW: usize = 24;
/// Surface-EMG electrode channels on the bracelet.
pub const EMG_CHANNELS: usize = 8;
/// Gesture classes: rest, fist, wrist flexion, wrist extension.
pub const EMG_CLASSES: usize = 4;

/// Samples in one extracted ECG beat window (centered on the R peak).
pub const ECG_WINDOW: usize = 64;
/// Beat classes: normal sinus, ventricular ectopic, supraventricular.
pub const ECG_CLASSES: usize = 3;

/// EEG electrode channels (C3/C4/Cz/Pz montage).
pub const EEG_CHANNELS: usize = 4;
/// Spectral bands per channel (theta, alpha/µ, beta, gamma).
pub const EEG_BANDS: usize = 4;

/// 8-channel surface-EMG hand-gesture windows (bracelet case study A).
///
/// Each sample is a rectified-envelope window of [`EMG_CHANNELS`] ×
/// [`EMG_WINDOW`] samples, flattened channel-major to 192 inputs — the
/// `192-100-4` MLP's input layer reads it directly, no offline feature
/// extraction. Per class, a fixed synergy vector decides how strongly
/// each channel activates, and a raised-cosine burst with a
/// class-specific onset shapes the contraction inside the window; the
/// rest class is baseline noise only. Targets are one-hot over
/// [`EMG_CLASSES`].
pub fn emg(seed: u64) -> TrainData {
    emg_sized(seed, 250)
}

/// [`emg`] with an explicit per-class sample count (the `--quick` paper
/// pipeline shrinks the dataset through this).
pub fn emg_sized(seed: u64, samples_per_class: usize) -> TrainData {
    let mut rng = Rng::new(seed ^ 0xE36_0001);
    let n_in = EMG_CHANNELS * EMG_WINDOW;
    let mut data = TrainData::new(n_in, EMG_CLASSES);

    // Per-class muscle synergies: which electrodes fire, and how hard.
    // Drawn from class-tagged forks so the pattern of class `c` does not
    // depend on how many draws earlier classes consumed.
    let mut synergy = vec![vec![0.0f32; EMG_CHANNELS]; EMG_CLASSES];
    let mut onset = vec![0.0f32; EMG_CLASSES];
    for c in 1..EMG_CLASSES {
        let mut class_rng = rng.fork(c as u64);
        for s in synergy[c].iter_mut() {
            // Sparse-ish synergies: a few dominant channels per gesture.
            let u = class_rng.range_f32(0.0, 1.0);
            *s = if u > 0.55 { class_rng.range_f32(0.6, 1.0) } else { class_rng.range_f32(0.0, 0.15) };
        }
        onset[c] = class_rng.range_f32(0.1, 0.4);
    }

    let mut input = vec![0.0f32; n_in];
    let mut target = vec![0.0f32; EMG_CLASSES];
    for c in 0..EMG_CLASSES {
        for _ in 0..samples_per_class {
            // Per-repetition contraction strength (inter-trial variance).
            let effort = rng.range_f32(0.7, 1.3);
            for ch in 0..EMG_CHANNELS {
                for t in 0..EMG_WINDOW {
                    let phase = t as f32 / (EMG_WINDOW - 1) as f32;
                    // Raised-cosine burst after the class onset.
                    let burst = if phase >= onset[c] {
                        let p = (phase - onset[c]) / (1.0 - onset[c]).max(1e-6);
                        0.5 * (1.0 - (std::f32::consts::TAU * p).cos()) + 0.5 * p
                    } else {
                        0.0
                    };
                    // Rectified-EMG envelope: amplitude-modulated |noise|
                    // plus electrode baseline noise.
                    let mav = synergy[c][ch] * effort * burst;
                    let hum = 0.04 * rng.gaussian().abs() as f32;
                    input[ch * EMG_WINDOW + t] =
                        mav * (0.55 + 0.45 * rng.gaussian().abs() as f32) + hum;
                }
            }
            target.iter_mut().for_each(|v| *v = 0.0);
            target[c] = 1.0;
            data.push(&input, &target);
        }
    }
    data.shuffle(&mut rng);
    data
}

/// Single-lead ECG beat windows for heartbeat/arrhythmia detection
/// (bracelet case study B).
///
/// Each sample is one [`ECG_WINDOW`]-sample beat centered on the QRS
/// complex, synthesized as a sum of Gaussian bumps (P wave, Q-R-S
/// deflections, T wave) with class-dependent morphology:
///
/// * **normal** — narrow QRS, distinct P wave, upright T;
/// * **ventricular ectopic** — wide high-amplitude QRS, no P wave,
///   inverted T (the classic PVC shape);
/// * **supraventricular ectopic** — narrow QRS with the P wave merged
///   into the preceding T (early atrial beat), slightly lower R.
///
/// Baseline wander (slow sine of random phase) and measurement noise
/// ride on every beat. Targets are one-hot over [`ECG_CLASSES`].
pub fn ecg(seed: u64) -> TrainData {
    ecg_sized(seed, 300)
}

/// [`ecg`] with an explicit per-class sample count.
pub fn ecg_sized(seed: u64, samples_per_class: usize) -> TrainData {
    let mut rng = Rng::new(seed ^ 0xEC6_0002);
    let mut data = TrainData::new(ECG_WINDOW, ECG_CLASSES);

    // One Gaussian bump centered at `mu` (in window fraction) with
    // width `sigma` and signed amplitude `a`.
    let bump = |t: f32, mu: f32, sigma: f32, a: f32| -> f32 {
        let d = (t - mu) / sigma;
        a * (-0.5 * d * d).exp()
    };

    let mut input = vec![0.0f32; ECG_WINDOW];
    let mut target = vec![0.0f32; ECG_CLASSES];
    for c in 0..ECG_CLASSES {
        for _ in 0..samples_per_class {
            // Beat-to-beat variability.
            let jitter = rng.range_f32(-0.02, 0.02);
            let gain = rng.range_f32(0.85, 1.15);
            let wander_phase = rng.range_f32(0.0, std::f32::consts::TAU);
            let (qrs_w, r_amp, t_amp, p_amp) = match c {
                // normal: narrow QRS, P present, upright T
                0 => (0.018, 1.0, 0.30, 0.15),
                // ventricular: wide tall QRS, no P, inverted T
                1 => (0.055, 1.35, -0.35, 0.0),
                // supraventricular: narrow QRS, early/absent P, lower R
                _ => (0.020, 0.85, 0.28, 0.04),
            };
            for (t_idx, v) in input.iter_mut().enumerate() {
                let t = t_idx as f32 / (ECG_WINDOW - 1) as f32;
                let center = 0.5 + jitter;
                let mut y = 0.0;
                // P wave (lead-in), QRS complex, T wave (recovery).
                y += bump(t, center - 0.22, 0.03, p_amp);
                y += bump(t, center - 0.035, qrs_w * 1.2, -0.18 * r_amp); // Q
                y += bump(t, center, qrs_w, r_amp); // R
                y += bump(t, center + 0.045, qrs_w * 1.4, -0.28 * r_amp); // S
                y += bump(t, center + 0.24, 0.055, t_amp);
                // Baseline wander + sensor noise.
                y += 0.05 * (std::f32::consts::TAU * t + wander_phase).sin();
                y += 0.025 * rng.gaussian() as f32;
                *v = gain * y;
            }
            target.iter_mut().for_each(|v| *v = 0.0);
            target[c] = 1.0;
            data.push(&input, &target);
        }
    }
    data.shuffle(&mut rng);
    data
}

/// EEG/BMI-style binary movement-intention detector (bracelet case
/// study C): [`EEG_CHANNELS`] × [`EEG_BANDS`] log band-powers, one
/// sigmoid output (1 = movement intention, 0 = rest).
///
/// The movement class models µ-rhythm event-related desynchronization:
/// alpha/µ power drops and beta power rises over the sensorimotor
/// channels (C3/C4, channels 0–1), while the parieto-central channels
/// move much less. Band powers are log-normal around per-band baselines
/// so the features are smooth and unbounded the way real band-power
/// estimates are.
pub fn eeg(seed: u64) -> TrainData {
    eeg_sized(seed, 400)
}

/// [`eeg`] with an explicit per-class sample count.
pub fn eeg_sized(seed: u64, samples_per_class: usize) -> TrainData {
    let mut rng = Rng::new(seed ^ 0xEE6_0003);
    let n_in = EEG_CHANNELS * EEG_BANDS;
    let mut data = TrainData::new(n_in, 1);

    // Resting log-power baseline per band: theta, alpha/µ, beta, gamma.
    const BASE: [f32; EEG_BANDS] = [1.2, 1.8, 0.9, 0.4];

    let mut input = vec![0.0f32; n_in];
    for class in 0..2usize {
        for _ in 0..samples_per_class {
            // Session-level scalp conductivity factor (shared across
            // channels of one sample).
            let session = rng.range_f32(-0.2, 0.2);
            for ch in 0..EEG_CHANNELS {
                // Sensorimotor channels carry the ERD signature.
                let motor = if ch < 2 { 1.0 } else { 0.25 };
                for b in 0..EEG_BANDS {
                    let mut mean = BASE[b];
                    if class == 1 {
                        match b {
                            1 => mean -= 0.8 * motor, // µ suppression
                            2 => mean += 0.5 * motor, // beta rise
                            _ => {}
                        }
                    }
                    input[ch * EEG_BANDS + b] =
                        mean + session + rng.normal_f32(0.0, 0.35);
                }
            }
            data.push(&input, &[class as f32]);
        }
    }
    data.shuffle(&mut rng);
    data
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_sizes() {
        let d = emg(1);
        assert_eq!((d.num_inputs, d.num_outputs, d.len()), (192, 4, 1000));
        let d = ecg(1);
        assert_eq!((d.num_inputs, d.num_outputs, d.len()), (64, 3, 900));
        let d = eeg(1);
        assert_eq!((d.num_inputs, d.num_outputs, d.len()), (16, 1, 800));
    }

    #[test]
    fn deterministic_per_seed_and_distinct_across_seeds() {
        for gen in [emg, ecg, eeg] {
            let a = gen(42);
            let b = gen(42);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!(a.targets, b.targets);
            let c = gen(43);
            assert_ne!(a.inputs, c.inputs);
        }
    }

    #[test]
    fn classes_balanced() {
        let d = emg(5);
        for c in 0..EMG_CLASSES {
            assert_eq!((0..d.len()).filter(|&i| d.label(i) == c).count(), 250);
        }
        let d = ecg(5);
        for c in 0..ECG_CLASSES {
            assert_eq!((0..d.len()).filter(|&i| d.label(i) == c).count(), 300);
        }
        let d = eeg(5);
        assert_eq!((0..d.len()).filter(|&i| d.label(i) == 1).count(), 400);
    }

    #[test]
    fn emg_rest_class_is_quietest() {
        // Mean rectified amplitude of the rest class must sit below every
        // gesture class — the physiological sanity the classifier leans on.
        let d = emg(7);
        let mut sum = [0.0f64; EMG_CLASSES];
        let mut cnt = [0usize; EMG_CLASSES];
        for i in 0..d.len() {
            let c = d.label(i);
            sum[c] += d.input(i).iter().map(|&v| v.abs() as f64).sum::<f64>();
            cnt[c] += 1;
        }
        let mean: Vec<f64> = (0..EMG_CLASSES).map(|c| sum[c] / cnt[c] as f64).collect();
        for c in 1..EMG_CLASSES {
            assert!(mean[0] < mean[c], "rest {} !< class {c} {}", mean[0], mean[c]);
        }
    }

    #[test]
    fn ecg_ventricular_beats_are_wider() {
        // Width proxy: energy outside the narrow QRS core. Ventricular
        // ectopics (class 1) must carry more of it than normal beats.
        let d = ecg(7);
        let width_proxy = |x: &[f32]| -> f64 {
            let core = ECG_WINDOW / 2;
            x.iter()
                .enumerate()
                .filter(|(i, _)| i.abs_diff(core) > 4 && i.abs_diff(core) < 12)
                .map(|(_, &v)| (v as f64).abs())
                .sum()
        };
        let mut sums = [0.0f64; ECG_CLASSES];
        let mut cnt = [0usize; ECG_CLASSES];
        for i in 0..d.len() {
            sums[d.label(i)] += width_proxy(d.input(i));
            cnt[d.label(i)] += 1;
        }
        assert!(sums[1] / cnt[1] as f64 > sums[0] / cnt[0] as f64);
    }

    #[test]
    fn eeg_movement_suppresses_mu_on_motor_channels() {
        let d = eeg(7);
        let mut mu = [0.0f64; 2];
        let mut cnt = [0usize; 2];
        for i in 0..d.len() {
            let c = d.label(i);
            // Alpha/µ band of the two sensorimotor channels.
            mu[c] += (d.input(i)[1] + d.input(i)[EEG_BANDS + 1]) as f64;
            cnt[c] += 1;
        }
        assert!(mu[1] / cnt[1] as f64 < mu[0] / cnt[0] as f64);
    }
}
