//! Synthetic dataset generators for the paper's application showcases.
//!
//! The paper's datasets (Myo-armband EMG/IMU features, insole
//! pressure + accelerometer features, waist-accelerometer windows) are
//! not public; runtime/energy depend only on topology, and accuracy only
//! needs to land near the published numbers (A 85.58 %, B 84 %,
//! C 94.6 %). We generate Gaussian class clusters in feature space with
//! per-class means on a scaled hypersphere; the `separation / spread`
//! ratio tunes achievable accuracy (DESIGN.md §1 records the
//! substitution).

pub mod wearable;

pub use wearable::{ecg, ecg_sized, eeg, eeg_sized, emg, emg_sized};

use crate::fann::TrainData;
use crate::util::rng::Rng;

/// Parameters of a synthetic classification dataset.
#[derive(Debug, Clone, Copy)]
pub struct SyntheticSpec {
    /// Input features per sample.
    pub num_features: usize,
    /// Number of classes.
    pub num_classes: usize,
    /// Samples generated per class.
    pub samples_per_class: usize,
    /// Distance scale of class means from the origin.
    pub separation: f32,
    /// Within-class standard deviation.
    pub spread: f32,
    /// RNG seed (datasets are deterministic per seed).
    pub seed: u64,
}

/// Generate a dataset: class means drawn once, samples are mean + noise,
/// targets one-hot (or a single sigmoid unit for 2-class/1-output nets
/// when `one_hot == false`).
pub fn generate(spec: SyntheticSpec, one_hot: bool) -> TrainData {
    let mut rng = Rng::new(spec.seed);
    let num_outputs = if one_hot { spec.num_classes } else { 1 };
    let mut data = TrainData::new(spec.num_features, num_outputs);

    // Class means: random directions scaled to `separation`.
    let mut means = Vec::with_capacity(spec.num_classes);
    for c in 0..spec.num_classes {
        let mut m: Vec<f32> = (0..spec.num_features)
            .map(|_| rng.fork(c as u64).gaussian() as f32)
            .collect();
        let norm = m.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
        m.iter_mut().for_each(|v| *v *= spec.separation / norm);
        means.push(m);
    }

    let mut input = vec![0.0f32; spec.num_features];
    let mut target = vec![0.0f32; num_outputs];
    for c in 0..spec.num_classes {
        for _ in 0..spec.samples_per_class {
            for (k, v) in input.iter_mut().enumerate() {
                *v = means[c][k] + rng.normal_f32(0.0, spec.spread);
            }
            target.iter_mut().for_each(|v| *v = 0.0);
            if one_hot {
                target[c] = 1.0;
            } else {
                target[0] = c as f32;
            }
            data.push(&input, &target);
        }
    }
    data.shuffle(&mut rng);
    data
}

/// Application A — hand-gesture recognition [47]: 76 time-domain EMG+IMU
/// features, 10 gestures. Separation tuned for ~85 % test accuracy.
pub fn gesture(seed: u64) -> TrainData {
    generate(
        SyntheticSpec {
            num_features: 76,
            num_classes: 10,
            samples_per_class: 300,
            separation: 3.8,
            spread: 1.0,
            seed,
        },
        true,
    )
}

/// Application B — fall-risk classification [48]: 117 pressure +
/// accelerometer features, faller / non-faller. ~84 % accuracy.
pub fn fall(seed: u64) -> TrainData {
    generate(
        SyntheticSpec {
            num_features: 117,
            num_classes: 2,
            samples_per_class: 250,
            separation: 1.5,
            spread: 1.0,
            seed,
        },
        true,
    )
}

/// Application C — human-activity classification [46]: 7 accelerometer
/// window features, 5 activities. ~94.6 % accuracy.
pub fn activity(seed: u64) -> TrainData {
    generate(
        SyntheticSpec {
            num_features: 7,
            num_classes: 5,
            samples_per_class: 200,
            separation: 3.4,
            spread: 1.0,
            seed,
        },
        true,
    )
}

/// The XOR toy problem (FANN's canonical quickstart).
pub fn xor() -> TrainData {
    let mut d = TrainData::new(2, 1);
    d.push(&[0.0, 0.0], &[0.0]);
    d.push(&[0.0, 1.0], &[1.0]);
    d.push(&[1.0, 0.0], &[1.0]);
    d.push(&[1.0, 1.0], &[0.0]);
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_shapes() {
        let d = gesture(1);
        assert_eq!(d.num_inputs, 76);
        assert_eq!(d.num_outputs, 10);
        assert_eq!(d.len(), 3000);
        let d = fall(1);
        assert_eq!((d.num_inputs, d.num_outputs, d.len()), (117, 2, 500));
        let d = activity(1);
        assert_eq!((d.num_inputs, d.num_outputs, d.len()), (7, 5, 1000));
    }

    #[test]
    fn one_hot_targets_valid() {
        let d = activity(2);
        for i in 0..d.len() {
            let t = d.target(i);
            assert_eq!(t.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(t.iter().filter(|&&v| v == 0.0).count(), 4);
        }
    }

    #[test]
    fn classes_balanced_after_shuffle() {
        let d = fall(3);
        let ones = (0..d.len()).filter(|&i| d.label(i) == 1).count();
        assert_eq!(ones, 250);
        // Shuffled: the first 20 samples are not all one class.
        let first: Vec<usize> = (0..20).map(|i| d.label(i)).collect();
        assert!(first.iter().any(|&l| l == 0) && first.iter().any(|&l| l == 1));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gesture(9);
        let b = gesture(9);
        assert_eq!(a.inputs, b.inputs);
        let c = gesture(10);
        assert_ne!(a.inputs, c.inputs);
    }

    #[test]
    fn higher_separation_is_more_separable() {
        // Nearest-class-mean accuracy should increase with separation.
        let acc = |sep: f32| -> f32 {
            let d = generate(
                SyntheticSpec {
                    num_features: 7,
                    num_classes: 5,
                    samples_per_class: 100,
                    separation: sep,
                    spread: 1.0,
                    seed: 5,
                },
                true,
            );
            // 1-NN to class centroids estimated from the data itself.
            let mut centroids = vec![vec![0.0f32; 7]; 5];
            let mut counts = vec![0usize; 5];
            for i in 0..d.len() {
                let c = d.label(i);
                counts[c] += 1;
                for k in 0..7 {
                    centroids[c][k] += d.input(i)[k];
                }
            }
            for c in 0..5 {
                centroids[c].iter_mut().for_each(|v| *v /= counts[c] as f32);
            }
            let mut correct = 0;
            for i in 0..d.len() {
                let x = d.input(i);
                let best = (0..5)
                    .min_by(|&a, &b| {
                        let da: f32 = (0..7).map(|k| (x[k] - centroids[a][k]).powi(2)).sum();
                        let db: f32 = (0..7).map(|k| (x[k] - centroids[b][k]).powi(2)).sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                if best == d.label(i) {
                    correct += 1;
                }
            }
            correct as f32 / d.len() as f32
        };
        assert!(acc(3.0) > acc(0.5));
    }
}
