//! # fann-on-mcu — reproduction of "FANN-on-MCU" (Wang et al., 2019)
//!
//! A deployment toolkit that takes multi-layer perceptrons trained with a
//! FANN-compatible library and deploys them, with memory-hierarchy-aware
//! placement and parallelization, onto modeled ARM Cortex-M and RISC-V
//! PULP (Mr. Wolf) targets.
//!
//! The crate is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the per-layer
//!   dense hot-spot, forward + backward, float and Q-format fixed point.
//! * **L2** — JAX model (`python/compile/model.py`): MLP forward / SGD
//!   training step, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** — this crate: the FANN substrate ([`fann`]), the deployment
//!   planner ([`deploy`]), cycle/energy MCU models ([`targets`]), the
//!   execution simulator ([`simulator`]), C code generation plus the
//!   machine-readable deploy plan ([`codegen`], `deploy emit`), the
//!   emitted-artifact emulator ([`emulator`], `deploy emulate` — runs
//!   generated deployments bit-exactly in CI without a cross-compiler),
//!   the PJRT runtime that loads the AOT artifacts ([`runtime`],
//!   `--features pjrt`), dataset generators ([`datasets`]), the paper's
//!   application showcases ([`apps`]), the benchmark harness
//!   ([`bench`]), and the multi-tenant inference host with adaptive
//!   micro-batching ([`service`], `service load`).
//!
//! # Kernel dispatch
//!
//! Every dense forward path — the float [`fann::Network`], the Q-format
//! [`fann::FixedNetwork`], the packed [`fann::PackedNetwork`], and the
//! simulator's [`simulator::Executable`] — executes its inner loop
//! through the [`kernels`] layer: the [`kernels::DenseKernel`] trait
//! (single-sample `matvec`, batched `matmul`, fused
//! `matvec_act`/`matmul_act` activation epilogues) implemented by
//! [`kernels::ScalarF32`], [`kernels::BlockedF32`] and
//! [`kernels::FixedQ`], plus the low-bitwidth packed pair
//! [`kernels::PackedQ7`] / [`kernels::PackedQ15`] over the word-packed
//! panel layout of [`kernels::layout`] (bit-exact vs `FixedQ`, built
//! offline by `FixedNetwork::pack`). Throughput workloads run many
//! samples per deployment plan via `run_batch` (and the
//! [`bench::batch`] persistent-pool parallel driver) instead of looping
//! single-sample inference — allocation-free in steady state through
//! the [`kernels::BatchScratch`] arena; per-sample numerics are
//! bit-identical either way, pinned by
//! `rust/tests/batch_consistency.rs`, `rust/tests/parity_kernels.rs`
//! and `rust/tests/parity_packed.rs`.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! `fann-on-mcu` binary is self-contained.
//!
//! # Reproducing the paper's results
//!
//! The `paper reproduce` CLI command runs the three wearable case
//! studies ([`apps::paper`]: EMG gesture, ECG arrhythmia, EEG/BMI
//! detection) end to end — train → quantize → pack → plan → emit →
//! emulate — across the modeled targets and writes the machine-readable
//! `PAPER_RESULTS.json` plus a rendered `RESULTS.md`
//! ([`bench::paper`]), including the paper's wolf-8core-vs-Cortex-M4
//! speedup and energy-reduction headline fields.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every figure/table of the paper to a bench target, and
//! `docs/ARCHITECTURE.md` for the end-to-end trace of one sample
//! through the stack.

#![warn(missing_docs)]

pub mod apps;
pub mod bench;
pub mod cli;
pub mod codegen;
pub mod datasets;
pub mod deploy;
pub mod emulator;
pub mod fann;
pub mod kernels;
pub mod quantize;
pub mod runtime;
pub mod service;
pub mod simulator;
pub mod targets;
pub mod util;
