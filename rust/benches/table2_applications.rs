//! Table II — the three application showcases on the four InfiniWolf
//! targets: runtime, average power, energy per classification, with the
//! relative improvements vs the Cortex-M4 in parentheses (the paper's
//! format), plus the amortized asymptotics (22×, −73 % etc.).

use fann_on_mcu::apps::{self, ACTIVITY, FALL, GESTURE};
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn main() {
    println!("=== Table II: application showcases (runtime / power / energy) ===");
    println!("    (relative improvements vs Cortex-M4 in parentheses)\n");

    let paper: [(&str, [f64; 4]); 3] = [
        // paper runtimes in ms per target for reference rows
        ("A", [17.6, 11.4, 5.7, 0.8]),
        ("B", [0.4, 0.3, 0.14, 0.03]),
        ("C", [0.03, 0.02, 0.01, 0.004]),
    ];

    let mut headline_speedup = 0.0;
    let mut headline_energy = 0.0;

    for (spec, seed, tag) in [(&GESTURE, 23u64, "A"), (&FALL, 21, "B"), (&ACTIVITY, 22, "C")] {
        let app = apps::train_app(spec, seed).unwrap();
        let data = spec.dataset(seed);
        let x = data.input(0);
        println!(
            "--- App {tag}: {} | topology {:?} | {} MACs | test acc {:.2}% (paper {:.2}%) ---",
            spec.title,
            spec.sizes,
            spec.macs(),
            app.test_accuracy * 100.0,
            spec.paper_accuracy * 100.0
        );

        let mut t = Table::new(vec!["target", "runtime", "power", "energy", "paper runtime"]);
        let mut m4: Option<(f64, f64)> = None;
        let paper_row = paper.iter().find(|(p, _)| *p == tag).unwrap().1;
        for (i, target) in Target::table2_targets().into_iter().enumerate() {
            let (_, r) = apps::run_on_target(&app, target, x).unwrap();
            let (speed_note, energy_note) = match m4 {
                None => {
                    m4 = Some((r.seconds, r.energy_uj));
                    ("".to_string(), "".to_string())
                }
                Some((t0, e0)) => (
                    format!(" ({:.2}x)", t0 / r.seconds),
                    format!(" ({:+.2}%)", (r.energy_uj - e0) / e0 * 100.0),
                ),
            };
            t.row(vec![
                target.label(),
                format!("{}{}", fmt_time(r.seconds), speed_note),
                format!("{:.2} mW", r.active_mw),
                format!("{}{}", fmt_energy(r.energy_uj * 1e-6), energy_note),
                format!("{} ms", paper_row[i]),
            ]);
            if tag == "A" && target == (Target::WolfCluster { cores: 8 }) {
                let (t0, e0) = m4.unwrap();
                headline_speedup = t0 / r.seconds;
                headline_energy = (1.0 - r.energy_uj / e0) * 100.0;
            }
        }
        t.print();
        println!();
    }

    println!("headline (app A, continuous classification):");
    println!("  speedup 8xRI5CY vs Cortex-M4: {headline_speedup:.1}x (paper: 22x)");
    println!("  energy reduction:             {headline_energy:.1}% (paper: 73.1%)");
    assert!((17.0..=27.0).contains(&headline_speedup));
    assert!((60.0..=85.0).contains(&headline_energy));
    println!("shape check OK");
}
