//! Fig. 8 — runtime (cycles) of a single layer over the (inputs, outputs)
//! grid, fixed-point.
//!
//! (a) ARM Cortex-M4 (STM32L475VG): the `*` marks cells where the layer
//!     no longer fits RAM and runs from flash (the paper's blue grid);
//! (b) IBEX (Mr. Wolf FC): `+` marks private-L2 → shared-L2 spill
//!     (purple dotted grid). `0.0` = does not fit at all.

use fann_on_mcu::bench::{fig8_grid, single_layer_cycles};
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::targets::{Chip, DataType, Region, Target};
use fann_on_mcu::util::table::Table;

fn grid_for(target: Target, spill_region: Region, marker: char) {
    let grid = fig8_grid();
    let mut header: Vec<String> = vec!["in \\ out".to_string()];
    header.extend(grid.iter().map(|o| o.to_string()));
    let mut t = Table::new(header);
    for &n_in in &grid {
        let mut row = vec![n_in.to_string()];
        for &n_out in &grid {
            let cell = match single_layer_cycles(n_in, n_out, target, DataType::Fixed) {
                None => "0.0".to_string(),
                Some(cycles) => {
                    let plan =
                        deploy::plan(&NetShape::new(&[n_in, n_out]), target, DataType::Fixed)
                            .unwrap();
                    let mark = if plan.region == spill_region { marker } else { ' ' };
                    format!("{:.0}{}", cycles, mark)
                }
            };
            row.push(cell);
        }
        t.row(row);
    }
    t.print();
}

fn main() {
    println!("=== Fig. 8a: single-layer cycles, Cortex-M4 (STM32L475VG), fixed ===");
    println!("    (* = layer in flash — the paper's blue-grid region)\n");
    grid_for(
        Target::CortexM4(Chip::Stm32l475vg),
        Region::Flash,
        '*',
    );

    println!("\n=== Fig. 8b: single-layer cycles, IBEX (Mr. Wolf FC), fixed ===");
    println!("    (+ = layer in shared L2 — the paper's purple-dotted region)\n");
    grid_for(Target::WolfFc, Region::SharedL2, '+');

    // Shape checks: cycles grow ~linearly in in*out; flash cells slower
    // than same-size RAM cells would be.
    let small = single_layer_cycles(64, 64, Target::CortexM4(Chip::Stm32l475vg), DataType::Fixed)
        .unwrap();
    let big = single_layer_cycles(128, 128, Target::CortexM4(Chip::Stm32l475vg), DataType::Fixed)
        .unwrap();
    assert!(big / small > 3.5 && big / small < 4.5, "{}", big / small);
    println!("\nshape check OK (4x MACs -> ~4x cycles)");
}
