//! Fig. 13 — end-to-end power trace of one application-A classification
//! on Mr. Wolf with 8 RI5CY cores: idle → cluster activation/init →
//! input DMA → parallel compute plateau → deactivation → idle.

use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::fann::{Activation, Network};
use fann_on_mcu::simulator::{self, CostOptions, Executable, PowerTrace};
use fann_on_mcu::targets::{power, DataType, Target};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::fmt_time;

fn main() {
    println!("=== Fig. 13: power trace, one app-A classification on 8x RI5CY ===\n");
    // Timing/power depend only on topology — random weights suffice.
    let mut rng = Rng::new(13);
    let mut net = Network::new(
        &[76, 300, 200, 100, 10],
        Activation::Tanh,
        Activation::Sigmoid,
    )
    .unwrap();
    net.randomize(&mut rng, None);
    let target = Target::WolfCluster { cores: 8 };
    let plan = deploy::plan(&NetShape::from(&net), target, DataType::Float32).unwrap();
    let x = vec![0.25f32; 76];
    let report =
        simulator::simulate(&plan, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
    let trace = PowerTrace::for_cluster_run(&report, target);

    println!("phases:");
    for p in &trace.phases {
        println!(
            "  {:<28} {:>10}   {:>7.2} mW",
            p.name,
            fmt_time(p.seconds),
            p.milliwatts
        );
    }

    println!("\nsampled trace (60 points, ASCII):");
    let samples = trace.sample(60);
    let peak = samples.iter().map(|s| s.1).fold(0.0, f64::max);
    for (t, mw) in &samples {
        let bar = "#".repeat((mw / peak * 50.0).round() as usize);
        println!("  {:>9} | {:>6.2} mW | {}", fmt_time(*t), mw, bar);
    }

    let overhead_uj: f64 = trace
        .phases
        .iter()
        .filter(|p| p.name.starts_with("cluster"))
        .map(|p| power::energy_uj(p.seconds, p.milliwatts))
        .sum();
    let compute_uj: f64 = trace
        .phases
        .iter()
        .filter(|p| p.name == "parallel compute")
        .map(|p| power::energy_uj(p.seconds, p.milliwatts))
        .sum();
    println!("\nconstant overhead: {overhead_uj:.1} µJ (paper: ~13 µJ)");
    println!("compute energy:    {compute_uj:.1} µJ (paper: ~54 µJ incl. input DMA)");
    println!("total:             {:.1} µJ", trace.total_energy_uj());

    assert!((11.0..=16.0).contains(&overhead_uj));
    assert!((35.0..=60.0).contains(&compute_uj));
    println!("shape check OK");
}
