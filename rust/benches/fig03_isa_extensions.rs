//! Fig. 3 — cycle reduction of the XPULP ISA extensions on the
//! dot-product inner loop (RV32IMC baseline → hardware loop →
//! post-increment loads → packed SIMD).
//!
//! Paper: hw-loop + post-increment ≈ 2×; with packed SIMD up to ≈ 10×.

use fann_on_mcu::targets::IsaExtensions;
use fann_on_mcu::util::table::Table;

fn main() {
    println!("=== Fig. 3: RISC-V ISA extension speedups (dot-product kernel) ===\n");
    let configs: [(&str, IsaExtensions); 5] = [
        ("RV32IMC baseline", IsaExtensions::BASELINE_RV32IMC),
        (
            "+ hardware loop",
            IsaExtensions {
                hardware_loop: true,
                post_increment: false,
                simd_lanes: 1,
            },
        ),
        ("+ post-incr load/store (XPULP)", IsaExtensions::XPULP_NO_SIMD),
        ("+ SIMD 2x16-bit", IsaExtensions::XPULP_SIMD2),
        ("+ SIMD 4x8-bit", IsaExtensions::XPULP_SIMD4),
    ];

    let mut t = Table::new(vec!["configuration", "cycles/MAC", "speedup vs RV32IMC"]);
    for (name, ext) in configs {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", ext.mac_cycles()),
            format!("{:.1}x", ext.speedup_vs_baseline()),
        ]);
    }
    t.print();

    let xpulp = IsaExtensions::XPULP_NO_SIMD.speedup_vs_baseline();
    let simd = IsaExtensions::XPULP_SIMD4.speedup_vs_baseline();
    println!("\npaper: ~2x (hw-loop + post-incr), ~10x (packed SIMD)");
    println!("model: {xpulp:.1}x, {simd:.1}x");
    assert!((1.9..=2.3).contains(&xpulp));
    assert!((8.0..=10.5).contains(&simd));
    println!("shape check OK");
}
