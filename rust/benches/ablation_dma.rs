//! Ablation: DMA double-buffering strategy (Sec. IV-B design choice).
//!
//! For L2-resident cluster networks the toolkit picks layer-wise
//! transfers when the largest layer double-buffers in L1 and falls back
//! to neuron-wise otherwise. This bench quantifies both strategies on
//! networks where *both* are feasible, plus a no-overlap strawman
//! (DMA setup + full payload on the critical path), showing what the
//! paper's double-buffering actually buys.

use fann_on_mcu::bench::bench_acts;
use fann_on_mcu::deploy::{self, DmaStrategy, NetShape};
use fann_on_mcu::simulator::cost::{network_cycles, CostOptions};
use fann_on_mcu::targets::{dma, DataType, Region, Target};
use fann_on_mcu::util::table::{fmt_cycles, Table};

/// Cycles with the DMA strategy forcibly overridden.
fn cycles_with(plan: &deploy::DeploymentPlan, strategy: Option<DmaStrategy>, acts_n: usize) -> f64 {
    let mut plan = plan.clone();
    plan.dma = strategy;
    network_cycles(&plan, &bench_acts(acts_n), CostOptions::default()).total()
}

/// No-overlap strawman: every byte of every layer is transferred on the
/// critical path before compute (what a naive memcpy port would do).
fn cycles_no_overlap(plan: &deploy::DeploymentPlan, acts_n: usize) -> f64 {
    let mut p = plan.clone();
    p.dma = None; // compute cycles without streaming terms
    let compute = network_cycles(&p, &bench_acts(acts_n), CostOptions::default()).total();
    let word = 4;
    let transfer: f64 = p
        .shape
        .sizes
        .windows(2)
        .map(|w| dma::WOLF_DMA.transfer_cycles((w[0] * w[1] + w[1]) * word))
        .sum();
    compute + transfer
}

fn main() {
    println!("=== Ablation: DMA strategy (layer-wise vs neuron-wise vs no overlap) ===\n");
    let target = Target::WolfCluster { cores: 8 };

    let mut t = Table::new(vec![
        "network",
        "auto choice",
        "layer-wise",
        "neuron-wise",
        "no overlap",
        "overlap gain",
    ]);
    for (name, sizes) in [
        // Both strategies feasible: layers individually fit L1.
        ("100-8x[48]-8 (L=16, d=8 family)", {
            let mut v = vec![100usize];
            v.extend((1..=16).map(|l| (l % 2 + l / 2) * 8));
            v.push(8);
            v
        }),
        ("50-100-60-100-60-8", vec![50, 100, 60, 100, 60, 8]),
        // Only neuron-wise feasible (app A: 300x200 layer > L1).
        ("app A 76-300-200-100-10", vec![76, 300, 200, 100, 10]),
    ] {
        let shape = NetShape::new(&sizes);
        let plan = deploy::plan(&shape, target, DataType::Fixed).unwrap();
        assert_eq!(plan.region, Region::SharedL2, "{name} must stream");
        let n = sizes.len() - 1;

        let auto = network_cycles(&plan, &bench_acts(n), CostOptions::default()).total();
        let layer_feasible = 2 * shape.max_layer_param_bytes(DataType::Fixed)
            <= fann_on_mcu::targets::memspec::WOLF_MEMORY.l1 - 8 * 1024;
        let lw = if layer_feasible {
            format!("{}", fmt_cycles(cycles_with(&plan, Some(DmaStrategy::LayerWise), n) as u64))
        } else {
            "infeasible".to_string()
        };
        let nw = cycles_with(&plan, Some(DmaStrategy::NeuronWise), n);
        let raw = cycles_no_overlap(&plan, n);
        t.row(vec![
            name.to_string(),
            format!("{:?} = {}", plan.dma.unwrap(), fmt_cycles(auto as u64)),
            lw,
            fmt_cycles(nw as u64),
            fmt_cycles(raw as u64),
            format!("{:.1}%", (raw - auto) / raw * 100.0),
        ]);
    }
    t.print();

    println!("\nfinding: double-buffering hides nearly the whole payload —");
    println!("the auto-selected strategy is within DMA-setup noise of the");
    println!("best feasible one, and the no-overlap strawman pays the full");
    println!("transfer on the critical path (the gap the paper's Sec. IV-B");
    println!("mechanism exists to close).");
}
