//! §Perf — wall-clock benchmarks of this library's own hot paths (the
//! things that must be fast on the *host*, as opposed to the modeled MCU
//! cycles): native float/fixed inference, the analytical sweep used by
//! the figure benches, and the PJRT forward/training step.
//!
//! Used by the EXPERIMENTS.md §Perf iteration log (before/after numbers).

use fann_on_mcu::bench::{fig11_shape, time_median, whole_network_cycles};
use fann_on_mcu::fann::{Activation, FixedNetwork, Network, Scratch};
#[cfg(feature = "pjrt")]
use fann_on_mcu::runtime::{ArtifactDir, PjrtTrainer, Runtime};
use fann_on_mcu::targets::{DataType, Target};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::Table;

fn main() {
    let mut rng = Rng::new(99);
    let mut net = Network::new(
        &[76, 300, 200, 100, 10],
        Activation::Tanh,
        Activation::Sigmoid,
    )
    .unwrap();
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let x: Vec<f32> = (0..76).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let xq = fixed.quantize_input(&x);

    let mut t = Table::new(vec!["hot path", "median", "throughput"]);

    // Native float inference (app-A topology, 103 800 MACs).
    let mut scratch = Scratch::for_network(&net);
    let tf = time_median(20, 200, || {
        std::hint::black_box(net.run_with(&mut scratch, &x));
    });
    t.row(vec![
        "native float forward (app A)".to_string(),
        format!("{:.1} µs", tf * 1e6),
        format!("{:.0} inf/s", 1.0 / tf),
    ]);

    // Native fixed inference.
    let tq = time_median(20, 200, || {
        std::hint::black_box(fixed.run_q(&xq));
    });
    t.row(vec![
        "native fixed forward (app A)".to_string(),
        format!("{:.1} µs", tq * 1e6),
        format!("{:.0} inf/s", 1.0 / tq),
    ]);

    // Analytical model sweep (the figure benches' workload):
    // 24 networks x 4 targets.
    let ts = time_median(3, 20, || {
        for l in 1..=24 {
            let shape = fig11_shape(l, 8);
            for target in [
                Target::CortexM4(fann_on_mcu::targets::Chip::Stm32l475vg),
                Target::WolfFc,
                Target::WolfCluster { cores: 1 },
                Target::WolfCluster { cores: 8 },
            ] {
                std::hint::black_box(whole_network_cycles(&shape, target, DataType::Fixed));
            }
        }
    });
    t.row(vec![
        "fig11/12 sweep (96 plans)".to_string(),
        format!("{:.1} µs", ts * 1e6),
        format!("{:.0} plans/s", 96.0 / ts),
    ]);

    // PJRT paths (need artifacts + the pjrt feature).
    #[cfg(feature = "pjrt")]
    if let Ok(art) = ArtifactDir::locate(None) {
        let rt = Runtime::cpu().unwrap();
        let mut trainer = PjrtTrainer::new(&rt, &art, "gesture", 7).unwrap();
        let tp = time_median(5, 50, || {
            std::hint::black_box(trainer.forward1(&x).unwrap());
        });
        t.row(vec![
            "PJRT forward b=1 (app A)".to_string(),
            format!("{:.1} µs", tp * 1e6),
            format!("{:.0} inf/s", 1.0 / tp),
        ]);

        let data = fann_on_mcu::datasets::gesture(7);
        let b = trainer.manifest.train_batch;
        let mut xb = vec![0.0f32; b * 76];
        let mut yb = vec![0.0f32; b * 10];
        for j in 0..b {
            xb[j * 76..(j + 1) * 76].copy_from_slice(data.input(j));
            yb[j * 10..(j + 1) * 10].copy_from_slice(data.target(j));
        }
        let tt = time_median(3, 30, || {
            std::hint::black_box(trainer.step(&xb, &yb).unwrap());
        });
        t.row(vec![
            "PJRT train step b=32 (app A)".to_string(),
            format!("{:.1} µs", tt * 1e6),
            format!("{:.0} steps/s", 1.0 / tt),
        ]);
    } else {
        eprintln!("(artifacts not built: skipping PJRT rows)");
    }
    #[cfg(not(feature = "pjrt"))]
    eprintln!("(pjrt feature off: skipping PJRT rows)");

    println!("=== §Perf: host hot-path benchmarks ===\n");
    t.print();

    // Roofline context for the native paths.
    let macs = 103_800.0;
    println!(
        "\nnative float: {:.2} GMAC/s | native fixed: {:.2} GMAC/s",
        macs / tf / 1e9,
        macs / tq / 1e9
    );
}
