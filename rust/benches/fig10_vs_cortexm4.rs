//! Fig. 10 — single-layer speedups of Mr. Wolf over the ARM Cortex-M4
//! (STM32L475VG), fixed point:
//!
//! (a) one RI5CY core vs M4 (≈ 2× thanks to XPULP; more when the M4
//!     falls into flash);
//! (b) 8 RI5CY cores vs M4 (≤ 13.5×).
//!
//! `0.0` = does not fit, `*` = M4 cell in flash, `~` = neuron-wise DMA.

use fann_on_mcu::bench::{fig8_grid, single_layer_cycles, speedup_cell};
use fann_on_mcu::deploy::{self, DmaStrategy, NetShape};
use fann_on_mcu::targets::{Chip, DataType, Region, Target};
use fann_on_mcu::util::table::Table;

fn main() {
    let grid = fig8_grid();
    let m4 = Target::CortexM4(Chip::Stm32l475vg);
    let single = Target::WolfCluster { cores: 1 };
    let multi = Target::WolfCluster { cores: 8 };

    let cell_mark = |n_in: usize, n_out: usize, wolf: Target| -> String {
        let shape = NetShape::new(&[n_in, n_out]);
        let mut marks = String::new();
        if let Ok(p) = deploy::plan(&shape, m4, DataType::Fixed) {
            if p.region == Region::Flash {
                marks.push('*');
            }
        }
        if let Ok(p) = deploy::plan(&shape, wolf, DataType::Fixed) {
            if p.dma == Some(DmaStrategy::NeuronWise) {
                marks.push('~');
            }
        }
        marks
    };

    for (title, wolf, paper_max, band) in [
        ("Fig. 10a: 1x RI5CY vs Cortex-M4", single, "2x", (1.2f64, 3.2f64)),
        ("Fig. 10b: 8x RI5CY vs Cortex-M4", multi, "13.5x", (9.0, 16.0)),
    ] {
        println!("=== {title} (fixed point) ===");
        println!("    (* = M4 in flash, ~ = cluster neuron-wise DMA)\n");
        let mut header: Vec<String> = vec!["in \\ out".to_string()];
        header.extend(grid.iter().map(|o| o.to_string()));
        let mut t = Table::new(header);
        let mut max_s = 0.0f64;
        for &n_in in &grid {
            let mut row = vec![n_in.to_string()];
            for &n_out in &grid {
                let base = single_layer_cycles(n_in, n_out, m4, DataType::Fixed);
                let new = single_layer_cycles(n_in, n_out, wolf, DataType::Fixed);
                if let (Some(a), Some(b)) = (base, new) {
                    max_s = max_s.max(a / b);
                }
                row.push(format!(
                    "{}{}",
                    speedup_cell(base, new),
                    cell_mark(n_in, n_out, wolf)
                ));
            }
            t.row(row);
        }
        t.print();
        println!("\nmax speedup: {max_s:.2}x (paper: up to {paper_max})\n");
        assert!(
            (band.0..=band.1).contains(&max_s),
            "{title}: modeled {max_s:.2}"
        );
    }
    println!("shape check OK");
}
