//! Table I — the inner-loop dot-product assembly and its cycle cost per
//! MAC on each core, plus the generated-C inner loops that encode them.

use fann_on_mcu::codegen::{self, NetSource};
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::targets::{Chip, Core, DataType, Target};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::Table;

fn main() {
    println!("=== Table I: inner-loop cycles per MAC ===\n");
    let mut t = Table::new(vec!["core", "float", "fixed", "notes"]);
    for (core, notes) in [
        (Core::CortexM4, "vldmia/vfma (float), 4x-unrolled ldr/mul/add (fixed)"),
        (Core::CortexM0, "no DSP/FPU; soft-float"),
        (Core::Ibex, "RV32IMC, 2-cycle loads, no FPU (soft-float)"),
        (Core::Riscy, "XPULP: p.lw post-incr + hw loop + fmadd.s"),
    ] {
        t.row(vec![
            core.name().to_string(),
            format!("{:.1}", core.mac_cycles(DataType::Float32)),
            format!("{:.1}", core.mac_cycles(DataType::Fixed)),
            notes.to_string(),
        ]);
    }
    t.print();

    println!("\npaper ratios: M4/RI5CY = 8/5 (float), 7/5 (fixed)");
    println!(
        "model ratios: {:.2}, {:.2}",
        Core::CortexM4.mac_cycles(DataType::Float32) / Core::Riscy.mac_cycles(DataType::Float32),
        Core::CortexM4.mac_cycles(DataType::Fixed) / Core::Riscy.mac_cycles(DataType::Fixed)
    );

    // Show the generated inner loops Table I describes.
    let mut rng = Rng::new(1);
    let mut net = Network::new(&[8, 4, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let shape = NetShape::from(&net);

    for (title, target, float) in [
        ("ARM Cortex-M4 float", Target::CortexM4(Chip::Stm32l475vg), true),
        ("ARM Cortex-M4 fixed", Target::CortexM4(Chip::Stm32l475vg), false),
        ("RI5CY float", Target::WolfCluster { cores: 1 }, true),
        ("RI5CY fixed", Target::WolfCluster { cores: 1 }, false),
    ] {
        println!("\n--- generated inner loop: {title} ---");
        let (plan, src) = if float {
            (
                deploy::plan(&shape, target, DataType::Float32).unwrap(),
                NetSource::Float(&net),
            )
        } else {
            (
                deploy::plan(&shape, target, DataType::Fixed).unwrap(),
                NetSource::Fixed(&fixed),
            )
        };
        let code = codegen::generate(&plan, src);
        print!("{}", code.file("fann_inner_loop.c").unwrap());
    }
}
