//! Fig. 9 — single-layer speedups on PULP Mr. Wolf:
//!
//! (a) one RI5CY core vs the IBEX FC (XPULP extensions; ≤ 2.2×, higher
//!     for large inputs where DMA setup amortizes);
//! (b) 8 RI5CY cores vs 1 (parallel speedup; ≤ 7.7×, lower for small
//!     layers where fork/barrier overhead dominates).
//!
//! `0.0` = does not fit; `~` marks neuron-wise-DMA cells (gray grid).

use fann_on_mcu::bench::{fig8_grid, single_layer_cycles, speedup_cell};
use fann_on_mcu::deploy::{self, DmaStrategy, NetShape};
use fann_on_mcu::targets::{DataType, Target};
use fann_on_mcu::util::table::Table;

fn dma_marker(n_in: usize, n_out: usize, target: Target) -> char {
    match deploy::plan(&NetShape::new(&[n_in, n_out]), target, DataType::Fixed) {
        Ok(p) if p.dma == Some(DmaStrategy::NeuronWise) => '~',
        Ok(p) if p.dma == Some(DmaStrategy::LayerWise) => '-',
        _ => ' ',
    }
}

fn main() {
    let grid = fig8_grid();
    let single = Target::WolfCluster { cores: 1 };
    let multi = Target::WolfCluster { cores: 8 };

    println!("=== Fig. 9a: 1x RI5CY speedup over IBEX (fixed point) ===");
    println!("    (~ = neuron-wise DMA, - = layer-wise DMA)\n");
    let mut header: Vec<String> = vec!["in \\ out".to_string()];
    header.extend(grid.iter().map(|o| o.to_string()));
    let mut t = Table::new(header.clone());
    let mut max_a = 0.0f64;
    for &n_in in &grid {
        let mut row = vec![n_in.to_string()];
        for &n_out in &grid {
            let ibex = single_layer_cycles(n_in, n_out, Target::WolfFc, DataType::Fixed);
            let riscy = single_layer_cycles(n_in, n_out, single, DataType::Fixed);
            if let (Some(a), Some(b)) = (ibex, riscy) {
                max_a = max_a.max(a / b);
            }
            row.push(format!(
                "{}{}",
                speedup_cell(ibex, riscy),
                dma_marker(n_in, n_out, single)
            ));
        }
        t.row(row);
    }
    t.print();
    println!("\nmax speedup: {max_a:.2}x (paper: up to 2.2x)\n");

    println!("=== Fig. 9b: 8x RI5CY parallel speedup over 1x ===\n");
    let mut t = Table::new(header);
    let mut max_b = 0.0f64;
    for &n_in in &grid {
        let mut row = vec![n_in.to_string()];
        for &n_out in &grid {
            let one = single_layer_cycles(n_in, n_out, single, DataType::Fixed);
            let eight = single_layer_cycles(n_in, n_out, multi, DataType::Fixed);
            if let (Some(a), Some(b)) = (one, eight) {
                max_b = max_b.max(a / b);
            }
            row.push(format!(
                "{}{}",
                speedup_cell(one, eight),
                dma_marker(n_in, n_out, multi)
            ));
        }
        t.row(row);
    }
    t.print();
    println!("\nmax parallel speedup: {max_b:.2}x (paper: up to 7.7x)");

    assert!((1.8..=2.5).contains(&max_a), "fig9a max {max_a}");
    assert!((6.5..=8.0).contains(&max_b), "fig9b max {max_b}");
    println!("shape check OK");
}
