//! Fig. 11 — whole-network runtime (cycles) while growing the number of
//! hidden layers per Eq. (3)/(4) with d = 8: 100 inputs, 8 outputs,
//! L = 1..24 hidden layers (8 to 1248 total hidden units).

use fann_on_mcu::bench::{eq4_total_hidden, fig11_shape, whole_network_cycles};
use fann_on_mcu::deploy::{self, DmaStrategy};
use fann_on_mcu::targets::{Chip, DataType, Region, Target};
use fann_on_mcu::util::table::Table;

fn main() {
    println!("=== Fig. 11: whole-network cycles vs number of hidden layers (d=8) ===\n");
    let targets: [(&str, Target, DataType); 4] = [
        ("M4 fixed", Target::CortexM4(Chip::Stm32l475vg), DataType::Fixed),
        ("IBEX fixed", Target::WolfFc, DataType::Fixed),
        ("1xRI5CY fixed", Target::WolfCluster { cores: 1 }, DataType::Fixed),
        ("8xRI5CY fixed", Target::WolfCluster { cores: 8 }, DataType::Fixed),
    ];

    let mut header = vec!["L".to_string(), "hidden units".to_string()];
    header.extend(targets.iter().map(|(n, _, _)| n.to_string()));
    header.push("wolf regime".to_string());
    let mut t = Table::new(header);

    for l in 1..=24 {
        let shape = fig11_shape(l, 8);
        let mut row = vec![l.to_string(), eq4_total_hidden(l, 8).to_string()];
        for (_, target, dtype) in targets {
            row.push(match whole_network_cycles(&shape, target, dtype) {
                Some(c) => format!("{c:.0}"),
                None => "0.0".to_string(),
            });
        }
        // Paper's annotations: L1 to 12 layers, layer-wise to 21,
        // neuron-wise beyond.
        let regime = match deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Fixed)
        {
            Ok(p) => match (p.region, p.dma) {
                (Region::L1, _) => "L1",
                (_, Some(DmaStrategy::LayerWise)) => "L2 layer-wise",
                (_, Some(DmaStrategy::NeuronWise)) => "L2 neuron-wise",
                (Region::NoFit, _) => "no fit",
                _ => "?",
            },
            Err(_) => "?",
        };
        row.push(regime.to_string());
        t.row(row);
    }
    t.print();

    // Paper: the net fits L1 up to 12 hidden layers (336 units).
    let p12 = deploy::plan(&fig11_shape(12, 8), Target::WolfCluster { cores: 8 }, DataType::Fixed)
        .unwrap();
    let p13 = deploy::plan(&fig11_shape(13, 8), Target::WolfCluster { cores: 8 }, DataType::Fixed)
        .unwrap();
    println!(
        "\nL1 boundary: L=12 -> {}, L=13 -> {} (paper: fits L1 up to 12 hidden layers)",
        p12.region.name(),
        p13.region.name()
    );
    assert_eq!(p12.region, Region::L1);
    assert_ne!(p13.region, Region::L1);
    println!("shape check OK");
}
