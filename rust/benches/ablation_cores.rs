//! Ablation (paper §VII, flagged as future work): "the trade-off between
//! the number of active cores, i.e. power consumption, and the parallel
//! speedup is to be analyzed" — we sweep 1..=8 RI5CY cores on the three
//! application topologies and report runtime, power, energy and the
//! energy-optimal core count.

use fann_on_mcu::bench::bench_acts;
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::simulator::cost::{network_cycles, utilization, CostOptions};
use fann_on_mcu::targets::{power, DataType, Target};
use fann_on_mcu::util::table::{fmt_energy, fmt_time, Table};

fn main() {
    println!("=== Ablation: active cores vs power vs speedup (paper §VII) ===\n");
    for (name, sizes) in [
        ("app A (gesture, 103800 MACs)", vec![76usize, 300, 200, 100, 10]),
        ("app B (fall, 2380 MACs)", vec![117, 20, 2]),
        ("app C (activity, 72 MACs)", vec![7, 6, 5]),
    ] {
        println!("--- {name} ---");
        let shape = NetShape::new(&sizes);
        let acts = bench_acts(sizes.len() - 1);
        let mut t = Table::new(vec![
            "cores", "runtime", "speedup", "power", "energy", "utilization",
        ]);
        let mut base = 0.0;
        let mut best = (1u32, f64::INFINITY);
        for cores in 1..=8u32 {
            let target = Target::WolfCluster { cores };
            let plan = deploy::plan(&shape, target, DataType::Fixed).unwrap();
            let cycles = network_cycles(&plan, &acts, CostOptions::default()).total();
            let secs = cycles / target.freq_hz();
            if cores == 1 {
                base = secs;
            }
            let util = utilization(&plan, &acts, CostOptions::default());
            let mw = power::WOLF_CLUSTER.active_mw(cores, util);
            let uj = power::energy_uj(secs, mw);
            if uj < best.1 {
                best = (cores, uj);
            }
            t.row(vec![
                cores.to_string(),
                fmt_time(secs),
                format!("{:.2}x", base / secs),
                format!("{mw:.2} mW"),
                fmt_energy(uj * 1e-6),
                format!("{:.0}%", util * 100.0),
            ]);
        }
        t.print();
        println!("energy-optimal core count: {} ({})\n", best.0, fmt_energy(best.1 * 1e-6));
    }

    println!("finding: large nets amortize the cluster infrastructure across");
    println!("cores (8 is energy-optimal); tiny nets with <8-neuron layers");
    println!("waste idle cores at the barrier and favor fewer cores — the");
    println!("quantified version of the paper's §VII conjecture.");
}
