//! Fig. 12 — whole-network speedups for the Eq. (3)/(4) family (d = 8):
//!
//! (a) on Mr. Wolf: 1×RI5CY vs IBEX, and 8× vs 1× (parallel speedup
//!     grows with network size, ~4.5× for the tiniest net, drop at the
//!     L1→L2 boundary);
//! (b) vs the Cortex-M4: IBEX ≈ M4, 1×RI5CY ≈ 2×, 8×RI5CY up to 11.1×
//!     once the M4 falls into flash.

use fann_on_mcu::bench::{eq4_total_hidden, fig11_shape, whole_network_cycles};
use fann_on_mcu::deploy::{self, DmaStrategy};
use fann_on_mcu::targets::{Chip, DataType, Region, Target};
use fann_on_mcu::util::table::Table;

fn ratio(a: Option<f64>, b: Option<f64>) -> Option<f64> {
    match (a, b) {
        (Some(x), Some(y)) if y > 0.0 => Some(x / y),
        _ => None,
    }
}

fn fmt(r: Option<f64>) -> String {
    r.map(|v| format!("{v:.2}")).unwrap_or_else(|| "0.0".into())
}

fn main() {
    let m4 = Target::CortexM4(Chip::Stm32l475vg);
    let fc = Target::WolfFc;
    let one = Target::WolfCluster { cores: 1 };
    let eight = Target::WolfCluster { cores: 8 };
    let dt = DataType::Fixed;

    println!("=== Fig. 12: whole-network speedups (d=8 family) ===\n");
    let mut t = Table::new(vec![
        "L",
        "hidden",
        "1xRI5CY/IBEX",
        "8x/1x RI5CY",
        "IBEX/M4",
        "1xRI5CY/M4",
        "8xRI5CY/M4",
        "regime",
    ]);

    let mut tiny_parallel = 0.0;
    let mut max_vs_m4: f64 = 0.0;
    for l in 1..=24 {
        let shape = fig11_shape(l, 8);
        let c_m4 = whole_network_cycles(&shape, m4, dt);
        let c_fc = whole_network_cycles(&shape, fc, dt);
        let c_1 = whole_network_cycles(&shape, one, dt);
        let c_8 = whole_network_cycles(&shape, eight, dt);

        let par = ratio(c_1, c_8);
        if l == 1 {
            tiny_parallel = par.unwrap();
        }
        if let Some(v) = ratio(c_m4, c_8) {
            max_vs_m4 = max_vs_m4.max(v);
        }
        let regime = match deploy::plan(&shape, eight, dt) {
            Ok(p) => match (p.region, p.dma) {
                (Region::L1, _) => "L1",
                (_, Some(DmaStrategy::LayerWise)) => "layer-wise",
                (_, Some(DmaStrategy::NeuronWise)) => "neuron-wise",
                _ => "-",
            },
            Err(_) => "-",
        };
        t.row(vec![
            l.to_string(),
            eq4_total_hidden(l, 8).to_string(),
            fmt(ratio(c_fc, c_1)),
            fmt(par),
            fmt(ratio(c_m4, c_fc)),
            fmt(ratio(c_m4, c_1)),
            fmt(ratio(c_m4, c_8)),
            regime.to_string(),
        ]);
    }
    t.print();

    println!("\nclaim checks (paper -> model):");
    println!("  tiny-net parallel speedup  ~4.5x -> {tiny_parallel:.2}x");
    println!("  max 8xRI5CY vs M4          11.1x -> {max_vs_m4:.2}x");
    assert!((3.5..=5.5).contains(&tiny_parallel), "{tiny_parallel}");
    assert!((8.0..=14.0).contains(&max_vs_m4), "{max_vs_m4}");
    println!("shape check OK");
}
