//! Fig. 7 — runtime of the Sec. V-A example network (5-100-100-3) before
//! and after the FANN-on-MCU optimizations, float vs fixed, plus the
//! Mr. Wolf comparison.
//!
//! Paper claims reproduced here:
//! * eliminating the redundant bias-buffer init: −3.1 % (float),
//!   −7.7 % (fixed) on the Cortex-M4;
//! * fixed ≈ 15 % faster than float on the M4;
//! * weight-matrix compute ≈ 88 % of total;
//! * single RI5CY ≈ 1.3×/1.4× faster than M4 (float/fixed);
//! * parallelization ≈ 6× over single RI5CY.

use fann_on_mcu::bench::bench_acts;
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::simulator::cost::{network_cycles, CostOptions};
use fann_on_mcu::targets::{Chip, DataType, Target};
use fann_on_mcu::util::table::{fmt_cycles, Table};

fn main() {
    println!("=== Fig. 7: example network 5-100-100-3 optimization steps ===\n");
    let shape = NetShape::new(&[5, 100, 100, 3]);
    let acts = bench_acts(3);
    let legacy = CostOptions {
        legacy_init: true,
        ..CostOptions::default()
    };
    let optimized = CostOptions::default();

    let mut t = Table::new(vec![
        "configuration",
        "cycles (FANNCortexM)",
        "cycles (FANN-on-MCU)",
        "gain",
    ]);
    let mut cells = Vec::new();
    for (label, target, dtype) in [
        (
            "Cortex-M4 float",
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Float32,
        ),
        (
            "Cortex-M4 fixed",
            Target::CortexM4(Chip::Stm32l475vg),
            DataType::Fixed,
        ),
        (
            "1x RI5CY float",
            Target::WolfCluster { cores: 1 },
            DataType::Float32,
        ),
        (
            "1x RI5CY fixed",
            Target::WolfCluster { cores: 1 },
            DataType::Fixed,
        ),
        (
            "8x RI5CY float",
            Target::WolfCluster { cores: 8 },
            DataType::Float32,
        ),
        (
            "8x RI5CY fixed",
            Target::WolfCluster { cores: 8 },
            DataType::Fixed,
        ),
    ] {
        let plan = deploy::plan(&shape, target, dtype).unwrap();
        let before = network_cycles(&plan, &acts, legacy).total();
        let after = network_cycles(&plan, &acts, optimized).total();
        t.row(vec![
            label.to_string(),
            fmt_cycles(before as u64),
            fmt_cycles(after as u64),
            format!("{:.1}%", (before - after) / before * 100.0),
        ]);
        cells.push((label, after));
    }
    t.print();

    // Claim checks.
    let m4f = cells[0].1;
    let m4q = cells[1].1;
    let w1f = cells[2].1;
    let w1q = cells[3].1;
    let w8f = cells[4].1;
    println!("\nclaim checks (paper -> model):");
    println!(
        "  fixed vs float on M4:  15% -> {:.1}%",
        (m4f - m4q) / m4f * 100.0
    );
    println!("  1xRI5CY vs M4 float:  1.3x -> {:.2}x", m4f / w1f);
    println!("  1xRI5CY vs M4 fixed:  1.4x -> {:.2}x", m4q / w1q);
    println!("  8x vs 1x RI5CY float: ~6x -> {:.2}x", w1f / w8f);

    // Profiling split (Fig. 7's stacked bars).
    let plan = deploy::plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
    let b = network_cycles(&plan, &acts, optimized);
    println!(
        "\nM4 float profile: weight-matrix {:.1}% | activation {:.1}% | overhead {:.1}% (paper: ~88% weight-matrix)",
        b.compute / b.total() * 100.0,
        b.activation / b.total() * 100.0,
        (b.overhead + b.dma + b.barrier) / b.total() * 100.0
    );
}
