//! §Perf — batched kernel-dispatch throughput: the acceptance bench for
//! the batch execution engine. Compares, on the host, for the same
//! 64-64-64-8 MLP (the ISSUE's reference topology):
//!
//! 1. looped single-sample `run_with` (the seed's only mode),
//! 2. single-thread `run_batch` (4×4 register-blocked matmul tiles),
//! 3. the `bench::batch` parallel driver (scoped threads × batched
//!    kernels),
//!
//! for the float path, plus the fixed-point (`run_q`) counterparts and
//! the packed Q7/Q15 kernels (serial + parallel). The shared
//! `bench::batch::measure_throughput` driver asserts all modes produce
//! bit-identical outputs within their representation (packed pinned to
//! a same-dec FixedQ reference) before timing them. Run with:
//! `cargo bench --bench perf_batch` (`BATCH=… THREADS=… REPS=…` env
//! overrides).

use fann_on_mcu::bench::batch;
use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::util::rng::Rng;
use fann_on_mcu::util::table::Table;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("BATCH", 256).max(1);
    let threads = env_usize("THREADS", 0);
    let reps = env_usize("REPS", 15).max(1);

    let sizes = [64usize, 64, 64, 8];
    let mut rng = Rng::new(1234);
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let n_in = net.num_inputs();
    let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let workers = batch::effective_workers(threads);

    println!(
        "=== §Perf: batched kernel dispatch ({}-{}-{}-{} MLP, {} MACs, batch {n}, {workers} worker(s)) ===\n",
        sizes[0], sizes[1], sizes[2], sizes[3],
        net.macs()
    );

    let rows = batch::measure_throughput(&net, &fixed, &xs, n, threads, 3, reps);
    println!("bit-exactness: all {} modes agree on {n} samples\n", rows.len());

    let mut t = Table::new(vec!["path", "batch time (µs)", "samples/s", "vs loop"]);
    for row in &rows {
        t.row(vec![
            row.name.to_string(),
            format!("{:.1}", row.seconds * 1e6),
            format!("{:.0}", n as f64 / row.seconds),
            format!("{:.2}x", row.baseline_seconds / row.seconds),
        ]);
    }
    t.print();

    // rows[0] is the looped float baseline; rows[1]/rows[2] the batched
    // float modes; rows[4] the serial fixed batch; rows[6] the serial
    // packed q7 batch (see measure_throughput's fixed ordering).
    let best = rows[1].seconds.min(rows[2].seconds);
    println!(
        "\nheadline: batched dispatch {:.2}x vs looped single-sample (target: >= 2x at batch >= 64)",
        rows[0].seconds / best
    );
    println!(
        "headline: packed q7 {:.2}x vs fixed_q single-thread (target: >= 1.5x)",
        rows[4].seconds / rows[6].seconds
    );
}
