//! Property tests for `quantize`'s Q-format primitives, on the
//! hand-rolled `util::proptest` harness:
//!
//! * quantize/dequantize round-trip error is bounded by one LSB,
//! * `quantize` and `sat_i32` saturate at both rails (never wrap),
//! * `qmul`'s arithmetic-shift semantics equal exact floor division
//!   (i128 reference) for the full i32 range, and the `f64` reference
//!   where f64 is exact.

use fann_on_mcu::quantize::{
    dequantize, qmul, quantize, sat_i32, I32_MAX, I32_MIN,
};
use fann_on_mcu::util::proptest::{check, ensure};

#[test]
fn quantize_dequantize_roundtrip_within_one_lsb() {
    check("roundtrip", 512, |rng| {
        let dec = rng.range_usize(1, 20) as u32;
        let v = rng.range_f32(-1000.0, 1000.0);
        let q = quantize(v, dec);
        let back = dequantize(q as i64, dec);
        let lsb = 1.0f32 / (1u64 << dec) as f32;
        ensure(
            (v - back).abs() <= lsb,
            format!("dec={dec} v={v} back={back}"),
        )
    });
}

#[test]
fn quantize_saturates_at_both_rails() {
    check("quantize saturation", 64, |rng| {
        let dec = rng.range_usize(1, 20) as u32;
        ensure(quantize(1e30, dec) == i32::MAX, "positive rail")?;
        ensure(quantize(-1e30, dec) == i32::MIN, "negative rail")?;
        ensure(quantize(f32::INFINITY, dec) == i32::MAX, "+inf")?;
        ensure(quantize(f32::NEG_INFINITY, dec) == i32::MIN, "-inf")?;
        // Just past the rail saturates; well inside does not.
        let max_exact = (i32::MAX as f64 / (1i64 << dec) as f64) as f32;
        ensure(
            quantize(max_exact * 2.0, dec) == i32::MAX,
            format!("2x rail dec={dec}"),
        )?;
        let v = rng.range_f32(-1.0, 1.0);
        let q = quantize(v, dec);
        ensure(
            q != i32::MAX && q != i32::MIN,
            format!("small value saturated: v={v} dec={dec}"),
        )
    });
}

#[test]
fn sat_i32_clamps_and_is_identity_inside() {
    check("sat_i32", 512, |rng| {
        // Inside the range: identity.
        let inside = rng.next_u64() as u32 as i32;
        ensure(sat_i32(inside as i64) == inside as i64, "identity inside")?;
        // Outside: clamps to the rails, for arbitrarily large excess.
        let excess = (rng.next_u64() >> 2) as i64; // non-negative
        ensure(sat_i32(I32_MAX + 1 + excess) == I32_MAX, "upper rail")?;
        ensure(sat_i32(I32_MIN - 1 - excess) == I32_MIN, "lower rail")?;
        ensure(sat_i32(i64::MAX) == I32_MAX, "i64::MAX")?;
        ensure(sat_i32(i64::MIN) == I32_MIN, "i64::MIN")
    });
}

#[test]
fn qmul_equals_exact_floor_division_full_range() {
    check("qmul vs i128 floor", 512, |rng| {
        let a = rng.next_u64() as u32 as i32;
        let b = rng.next_u64() as u32 as i32;
        let dec = rng.range_usize(1, 20) as u32;
        let got = qmul(a, b, dec);
        // Arithmetic shift right IS floor division by 2^dec; verify
        // against div_euclid (exact floor) in i128 so the product can
        // never overflow the reference.
        let want = ((a as i128) * (b as i128)).div_euclid(1i128 << dec);
        ensure(
            got as i128 == want,
            format!("a={a} b={b} dec={dec}: {got} != {want}"),
        )
    });
}

#[test]
fn qmul_matches_f64_reference_where_f64_is_exact() {
    check("qmul vs f64", 512, |rng| {
        // |a|,|b| < 2^25 keeps the product < 2^50: exactly representable
        // in f64, so floor(a*b / 2^dec) is the true mathematical value.
        let a = (rng.next_u64() % (1 << 26)) as i64 - (1 << 25);
        let b = (rng.next_u64() % (1 << 26)) as i64 - (1 << 25);
        let dec = rng.range_usize(1, 20) as u32;
        let got = qmul(a as i32, b as i32, dec);
        let want = ((a as f64) * (b as f64) / (1i64 << dec) as f64).floor() as i64;
        ensure(
            got == want,
            format!("a={a} b={b} dec={dec}: {got} != {want}"),
        )
    });
}

#[test]
fn dequantize_inverts_exact_grid_points() {
    check("grid exactness", 256, |rng| {
        // Any Q(dec) integer dequantizes to a float that re-quantizes to
        // itself (the grid is closed under the round trip).
        let dec = rng.range_usize(1, 20) as u32;
        // Keep the magnitude small enough that f32 represents the
        // dequantized value exactly (24-bit mantissa).
        let q = (rng.next_u64() % (1 << 23)) as i32 - (1 << 22);
        let v = dequantize(q as i64, dec);
        ensure(
            quantize(v, dec) == q,
            format!("dec={dec} q={q} v={v}"),
        )
    });
}
