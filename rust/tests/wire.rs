//! Wire front-end gate: the socket boundary must not weaken any
//! service-layer promise.
//!
//! End-to-end (satellite 2):
//!
//! * N concurrent UDS clients, interleaving models and tenants, get
//!   replies **bit-exact** vs in-process `submit()` on the very same
//!   service, with exactly one terminal reply per request id;
//! * a client that disconnects mid-stream (replies still in flight)
//!   leaks no connection task — `live_connections()` drains to zero
//!   and `connections_opened == connections_closed`;
//! * wire counters reconcile with the service snapshot at teardown;
//! * the TCP listener serves the identical protocol, and
//!   `shutdown_all` folds wire counters into the metrics snapshot;
//! * a request parked inside the service at shutdown is answered
//!   `Aborted` before its socket closes.
//!
//! Adversarial peers (satellite 3) — every scenario also proves a
//! concurrent well-behaved client stays served:
//!
//! * a byte-at-a-time sender (maximal partial reads) still gets its
//!   reply;
//! * a `len = u32::MAX` length prefix is answered `BadFrame` from the
//!   four prefix bytes alone (no allocation) and the connection is
//!   closed;
//! * a peer that connects and sends nothing is reaped at the read
//!   deadline;
//! * a peer that floods requests and never reads responses is bounded
//!   by the writer's deadline + bounded event channel (backpressure
//!   propagates to the reader) and torn down without deadlock.

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use fann_on_mcu::service::frame::{self, ResponseBody, ResponseFrame};
use fann_on_mcu::service::load::demo_registry;
use fann_on_mcu::service::wire::temp_uds_path;
use fann_on_mcu::service::{
    BatchPolicy, InferenceService, MetricsSnapshot, Output, RequestFrame, ShardPolicy, WireClient,
    WireConfig, WireCounters, WireServer,
};
use fann_on_mcu::util::rng::Rng;

/// A started sharded service behind a UDS wire server, plus the
/// `(id, n_in, n_out)` rows of its demo models.
struct Fixture {
    server: WireServer,
    path: PathBuf,
    models: Vec<(String, usize, usize)>,
}

fn start_fixture(tag: &str, cfg: &WireConfig, shards: usize, seed: u64) -> Fixture {
    let (registry, models) = demo_registry(seed).expect("demo registry builds");
    let policy = BatchPolicy {
        max_batch: 8,
        max_delay: Duration::from_micros(200),
        queue_capacity: 512,
        ..BatchPolicy::default()
    };
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &policy,
        &ShardPolicy::new(shards),
        None,
    ));
    let mut server = WireServer::start(svc, cfg);
    let path = temp_uds_path(tag);
    server.listen_uds(&path).expect("bind UDS listener");
    Fixture { server, path, models }
}

/// Tear a fixture's server and service down, returning the final
/// service snapshot and the wire counters.
fn teardown(server: WireServer) -> (MetricsSnapshot, WireCounters) {
    let (svc, counters) = server.shutdown();
    let Ok(svc) = Arc::try_unwrap(svc) else {
        panic!("service Arc still shared after wire shutdown");
    };
    (svc.shutdown(), counters)
}

/// Spin (5 ms granularity) until `cond` holds, panicking past `timeout`.
fn wait_until(timeout: Duration, what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + timeout;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Read one response frame off a raw socket (for adversarial peers
/// that bypass [`WireClient`]).
fn read_response(stream: &mut UnixStream) -> ResponseFrame {
    let mut prefix = [0u8; 4];
    stream.read_exact(&mut prefix).expect("read length prefix");
    let mut body = vec![0u8; u32::from_le_bytes(prefix) as usize];
    stream.read_exact(&mut body).expect("read response body");
    frame::decode_response(&body).expect("decode response")
}

/// Lockstep call that retries transient `Shed`/`Quarantined` replies —
/// the well-behaved client used alongside adversarial peers.
fn call_retrying_shed(client: &mut WireClient, req: &RequestFrame) -> ResponseFrame {
    for _ in 0..500 {
        let resp = client.call(req).expect("wire call");
        assert_eq!(resp.id, req.id, "terminal reply echoes the request id");
        match resp.body {
            ResponseBody::Shed { .. } | ResponseBody::Quarantined { .. } => {
                std::thread::sleep(Duration::from_millis(1));
            }
            _ => return resp,
        }
    }
    panic!("request {} still shed after 500 attempts", req.id);
}

#[test]
fn concurrent_uds_clients_match_in_process_submit_bit_for_bit() {
    const CLIENTS: usize = 6;
    const REQUESTS: usize = 40;
    const SAMPLES: usize = 8;
    let fx = start_fixture("bitexact", &WireConfig::default(), 2, 21);

    // Deterministic inputs per (model, sample) slot.
    let mut rng = Rng::new(0xF00D);
    let inputs: Vec<Vec<Vec<f32>>> = fx
        .models
        .iter()
        .map(|(_, n_in, _)| {
            (0..SAMPLES)
                .map(|_| (0..*n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect())
                .collect()
        })
        .collect();

    // Reference outputs via in-process submit() on the same service the
    // wire clients will hit — batching may differ, answers may not.
    let (tx, rx) = mpsc::channel();
    let mut expected: Vec<Vec<Output>> = Vec::new();
    for (mi, (id, _, _)) in fx.models.iter().enumerate() {
        let mut per = Vec::with_capacity(SAMPLES);
        for sample in inputs[mi].iter().take(SAMPLES) {
            let ticket = fx
                .server
                .service()
                .submit(id, 999, sample, &tx)
                .expect("reference submit accepted");
            let reply = rx.recv_timeout(Duration::from_secs(10)).expect("reference reply");
            assert_eq!(reply.ticket, ticket);
            per.push(reply.outcome.expect("reference inference succeeds"));
        }
        expected.push(per);
    }

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let (fx, inputs, expected) = (&fx, &inputs, &expected);
            handles.push(scope.spawn(move || {
                let mut client = WireClient::connect_uds(&fx.path).expect("connect");
                client
                    .set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(10)))
                    .expect("set client timeouts");
                for r in 0..REQUESTS {
                    // Interleave models and samples differently per
                    // client so neighbors never walk in lockstep.
                    let mi = (c + r) % fx.models.len();
                    let s = (c * 7 + r * 3) % SAMPLES;
                    let id = ((c as u64) << 32) | r as u64;
                    let req = RequestFrame {
                        id,
                        tenant: c as u64,
                        model: fx.models[mi].0.clone(),
                        input: inputs[mi][s].clone(),
                    };
                    let resp = client.call(&req).expect("wire call");
                    assert_eq!(resp.id, id, "terminal reply echoes the request id");
                    match resp.body {
                        ResponseBody::Ok { output, .. } => {
                            assert_eq!(
                                output, expected[mi][s],
                                "wire reply bit-exact vs in-process submit"
                            );
                        }
                        other => panic!("unexpected terminal reply {other:?}"),
                    }
                }
                // Half-close, then prove the server queued no stray
                // frame for this connection: with every id already
                // answered exactly once, the next read must be EOF.
                client.finish_sending().expect("half-close write side");
                assert!(client.recv().is_err(), "no extra frame after the last reply");
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
    });

    let wire_requests = (CLIENTS * REQUESTS) as u64;
    let reference = (fx.models.len() * SAMPLES) as u64;
    let (snap, w) = teardown(fx.server);
    assert_eq!(w.connections_opened, CLIENTS as u64);
    assert_eq!(w.connections_closed, CLIENTS as u64, "every connection wound down");
    assert_eq!(w.frames_rx, wire_requests, "one frame per request");
    assert_eq!(w.frames_tx, wire_requests, "exactly one terminal frame per id");
    assert_eq!(w.bad_frames, 0);
    assert!(w.bytes_rx > 0 && w.bytes_tx > 0);
    assert_eq!(
        snap.total_completed(),
        wire_requests + reference,
        "service counters reconcile with what clients saw"
    );
    assert_eq!(snap.total_failed(), 0);
    assert_eq!(snap.total_shed(), 0);
}

#[test]
fn mid_stream_disconnect_leaks_no_connection_task() {
    let cfg = WireConfig {
        read_timeout: Some(Duration::from_millis(500)),
        ..WireConfig::default()
    };
    let fx = start_fixture("disconnect", &cfg, 1, 33);
    let (model, n_in, _) = fx.models[0].clone();

    // Fire eight requests and vanish without reading a single reply —
    // the socket closes with replies still in flight.
    {
        let mut client = WireClient::connect_uds(&fx.path).expect("connect");
        for r in 0..8u64 {
            client
                .send(&RequestFrame {
                    id: r,
                    tenant: 1,
                    model: model.clone(),
                    input: vec![0.25; n_in],
                })
                .expect("send");
        }
    }

    // The reader/forwarder/writer trio must wind down on its own.
    wait_until(Duration::from_secs(5), "disconnected peer's tasks to drain", || {
        fx.server.live_connections() == 0
    });

    // The server keeps serving fresh connections afterwards.
    let mut well = WireClient::connect_uds(&fx.path).expect("connect");
    well.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
        .expect("set client timeouts");
    let resp = call_retrying_shed(
        &mut well,
        &RequestFrame { id: 77, tenant: 2, model, input: vec![0.5; n_in] },
    );
    assert!(matches!(resp.body, ResponseBody::Ok { .. }), "got {:?}", resp.body);
    drop(well);

    let (snap, w) = teardown(fx.server);
    assert_eq!(w.connections_opened, 2);
    assert_eq!(w.connections_closed, 2, "dead peer's connection was reaped");
    // All nine requests were answered service-side even though eight
    // replies had nowhere to go.
    assert_eq!(snap.total_completed() + snap.total_failed(), 9);
}

#[test]
fn byte_at_a_time_sender_is_still_served() {
    let fx = start_fixture("trickle", &WireConfig::default(), 1, 5);
    let (model, n_in, _) = fx.models[0].clone();
    let mut raw = UnixStream::connect(&fx.path).expect("connect raw");

    let req = RequestFrame { id: 424_242, tenant: 9, model, input: vec![0.125; n_in] };
    let mut buf = Vec::new();
    frame::encode_request(&req, &mut buf);
    // One byte per syscall, with periodic pauses so the server's reader
    // sees genuinely partial frames at arbitrary offsets.
    for (i, b) in buf.iter().enumerate() {
        raw.write_all(std::slice::from_ref(b)).expect("write one byte");
        if i % 32 == 0 {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    let resp = read_response(&mut raw);
    assert_eq!(resp.id, req.id);
    assert!(matches!(resp.body, ResponseBody::Ok { .. }), "got {:?}", resp.body);
    drop(raw);

    let (_, w) = teardown(fx.server);
    assert_eq!(w.bad_frames, 0, "a slow sender is not a protocol violation");
}

#[test]
fn oversized_length_prefix_is_answered_bad_frame_then_closed() {
    let fx = start_fixture("oversized", &WireConfig::default(), 1, 5);
    let (model, n_in, _) = fx.models[0].clone();

    let mut raw = UnixStream::connect(&fx.path).expect("connect raw");
    raw.write_all(&u32::MAX.to_le_bytes()).expect("write bogus prefix");
    // The reject is raised from the four prefix bytes alone — the body
    // is never awaited, so the reply arrives although we sent nothing
    // else.
    let resp = read_response(&mut raw);
    assert!(
        matches!(resp.body, ResponseBody::BadFrame { .. }),
        "oversized prefix answered BadFrame, got {:?}",
        resp.body
    );
    // After the protocol violation the server stops reading this peer.
    let mut one = [0u8; 1];
    assert!(
        matches!(raw.read(&mut one), Ok(0) | Err(_)),
        "connection closed after BadFrame"
    );
    drop(raw);

    // A well-behaved client on a fresh connection is unaffected.
    let mut well = WireClient::connect_uds(&fx.path).expect("connect");
    well.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
        .expect("set client timeouts");
    let resp = call_retrying_shed(
        &mut well,
        &RequestFrame { id: 1, tenant: 0, model, input: vec![0.1; n_in] },
    );
    assert!(matches!(resp.body, ResponseBody::Ok { .. }), "got {:?}", resp.body);
    drop(well);

    let (_, w) = teardown(fx.server);
    assert!(w.bad_frames >= 1, "the violation was counted");
    assert_eq!(w.connections_opened, w.connections_closed);
}

#[test]
fn silent_peer_is_reaped_at_the_read_deadline() {
    let cfg = WireConfig {
        read_timeout: Some(Duration::from_millis(150)),
        ..WireConfig::default()
    };
    let fx = start_fixture("silent", &cfg, 1, 5);

    let raw = UnixStream::connect(&fx.path).expect("connect raw");
    wait_until(Duration::from_secs(2), "silent peer to be accepted", || {
        fx.server.live_connections() >= 1
    });
    // Send nothing: the read deadline alone must reap the connection.
    wait_until(Duration::from_secs(5), "silent peer to hit the read deadline", || {
        fx.server.live_connections() == 0
    });
    drop(raw);

    // Still serviceable afterwards.
    let (model, n_in, _) = fx.models[0].clone();
    let mut well = WireClient::connect_uds(&fx.path).expect("connect");
    well.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
        .expect("set client timeouts");
    let resp = call_retrying_shed(
        &mut well,
        &RequestFrame { id: 3, tenant: 0, model, input: vec![0.4; n_in] },
    );
    assert!(matches!(resp.body, ResponseBody::Ok { .. }), "got {:?}", resp.body);
    drop(well);

    let (_, w) = teardown(fx.server);
    assert_eq!(w.connections_opened, 2);
    assert_eq!(w.connections_closed, 2);
}

#[test]
fn peer_that_stops_reading_responses_is_bounded_and_torn_down() {
    let cfg = WireConfig {
        max_in_flight: 4,
        read_timeout: Some(Duration::from_secs(2)),
        write_timeout: Some(Duration::from_millis(300)),
        ..WireConfig::default()
    };
    let fx = start_fixture("backpressure", &cfg, 1, 5);
    let (flood_model, flood_n_in, _) = fx.models[0].clone();
    let (well_model, well_n_in, _) = fx.models[1].clone();

    std::thread::scope(|scope| {
        let path = fx.path.clone();
        let flooder = scope.spawn(move || {
            let mut client = WireClient::connect_uds(&path).expect("connect");
            // The client's own write deadline is its exit: once server
            // backpressure (full writer channel → blocked reader →
            // full kernel buffers) reaches us, send() errors out
            // instead of deadlocking the test.
            client
                .set_timeouts(Some(Duration::from_millis(250)), Some(Duration::from_millis(250)))
                .expect("set client timeouts");
            let mut sent = 0u64;
            for i in 0..200_000u64 {
                let req = RequestFrame {
                    id: i,
                    tenant: 3,
                    model: flood_model.clone(),
                    input: vec![0.5; flood_n_in],
                };
                if client.send(&req).is_err() {
                    break;
                }
                sent += 1;
            }
            // Never read a single response; drop the flooded socket.
            sent
        });

        // While the flood runs, a well-behaved client on its own
        // connection keeps being served.
        let mut well = WireClient::connect_uds(&fx.path).expect("connect");
        well.set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
            .expect("set client timeouts");
        for i in 0..20u64 {
            let resp = call_retrying_shed(
                &mut well,
                &RequestFrame {
                    id: i,
                    tenant: 8,
                    model: well_model.clone(),
                    input: vec![0.25; well_n_in],
                },
            );
            assert!(matches!(resp.body, ResponseBody::Ok { .. }), "got {:?}", resp.body);
        }
        drop(well);

        let sent = flooder.join().expect("flooder thread");
        assert!(sent > 0, "flooder got at least one frame out");
    });

    // The stalled connection is torn down by the write deadline (or the
    // read deadline once the flood stops) — its thread trio never
    // leaks, and server memory stayed bounded by the in-flight cap plus
    // the bounded writer channel throughout.
    wait_until(Duration::from_secs(10), "flooded connection to be torn down", || {
        fx.server.live_connections() == 0
    });
    let (_, w) = teardown(fx.server);
    assert_eq!(w.connections_opened, w.connections_closed);
}

#[test]
fn tcp_endpoint_serves_the_same_protocol_and_shutdown_all_folds_counters() {
    let (registry, models) = demo_registry(9).expect("demo registry builds");
    let policy = BatchPolicy {
        max_batch: 4,
        max_delay: Duration::from_micros(200),
        ..BatchPolicy::default()
    };
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &policy,
        &ShardPolicy::new(1),
        None,
    ));
    let mut server = WireServer::start(svc, &WireConfig::default());
    let addr = server.listen_tcp("127.0.0.1:0").expect("bind TCP listener");

    let (model, n_in, _) = models[0].clone();
    let mut rng = Rng::new(0xAB);
    let input: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();

    // In-process reference on the same service.
    let (tx, rx) = mpsc::channel();
    let ticket = server.service().submit(&model, 4, &input, &tx).expect("submit");
    let reply = rx.recv_timeout(Duration::from_secs(10)).expect("reference reply");
    assert_eq!(reply.ticket, ticket);
    let expected = reply.outcome.expect("reference inference succeeds");

    let mut client = WireClient::connect_tcp(addr).expect("connect tcp");
    client
        .set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
        .expect("set client timeouts");
    let resp = client.call(&RequestFrame { id: 5, tenant: 4, model, input }).expect("tcp call");
    assert_eq!(resp.id, 5);
    match resp.body {
        ResponseBody::Ok { output, .. } => {
            assert_eq!(output, expected, "TCP reply bit-exact vs in-process submit");
        }
        other => panic!("unexpected terminal reply {other:?}"),
    }
    drop(client);

    // shutdown_all (the `service serve` teardown path) folds the wire
    // counters into the final snapshot.
    let snap = server.shutdown_all();
    assert_eq!(snap.wire.frames_rx, 1);
    assert_eq!(snap.wire.frames_tx, 1);
    assert_eq!(snap.wire.connections_opened, 1);
    assert_eq!(snap.wire.connections_closed, 1);
    assert_eq!(snap.total_completed(), 2);
}

#[test]
fn shutdown_answers_parked_requests_with_aborted() {
    let (registry, models) = demo_registry(13).expect("demo registry builds");
    // An un-flushable queue: huge batch trigger, hour-long deadline —
    // the request is accepted and then parks inside the service.
    let policy = BatchPolicy {
        max_batch: 64,
        max_delay: Duration::from_secs(3600),
        ..BatchPolicy::default()
    };
    let svc = Arc::new(InferenceService::start_sharded(
        registry,
        &policy,
        &ShardPolicy::new(1),
        None,
    ));
    let mut server = WireServer::start(svc, &WireConfig::default());
    let path = temp_uds_path("abort");
    server.listen_uds(&path).expect("bind UDS listener");

    let (model, n_in, _) = models[0].clone();
    let mut client = WireClient::connect_uds(&path).expect("connect");
    client
        .set_timeouts(Some(Duration::from_secs(10)), Some(Duration::from_secs(10)))
        .expect("set client timeouts");
    client
        .send(&RequestFrame { id: 31, tenant: 1, model, input: vec![0.3; n_in] })
        .expect("send");
    wait_until(Duration::from_secs(5), "request to park inside the service", || {
        server.service().metrics().total_requests() >= 1
    });

    // Shut down underneath the parked request: the contract is a
    // terminal `Aborted` frame before the socket closes.
    let reader = std::thread::spawn(move || client.recv().expect("terminal reply during shutdown"));
    let (svc, counters) = server.shutdown();
    let resp = reader.join().expect("reader thread");
    assert_eq!(resp.id, 31);
    assert!(
        matches!(resp.body, ResponseBody::Aborted { .. }),
        "parked request answered Aborted at shutdown, got {:?}",
        resp.body
    );
    assert_eq!(counters.frames_tx, 1);

    let Ok(svc) = Arc::try_unwrap(svc) else {
        panic!("service Arc still shared after wire shutdown");
    };
    let snap = svc.shutdown();
    assert_eq!(snap.total_failed(), 1, "the abort is a service-side failure");
}
