//! Packed-kernel parity: [`PackedQ7`]/[`PackedQ15`] must be **bit-exact**
//! against [`FixedQ`] on the same Q(dec) parameters — the packed panel
//! layout is storage reordering plus lossless narrowing, never a change
//! of arithmetic — across randomized shapes including `n_in % 4 != 0`
//! and `n_in < 4` ragged tails, at both network and raw-kernel level.
//! Also pins fused-vs-unfused epilogue equality for every kernel.

use fann_on_mcu::fann::activation::ALL as ALL_ACTS;
use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::layout::pack_rows;
use fann_on_mcu::kernels::{
    f32_kernels, DenseKernel, DenseLayerRef, FixedQ, PackedLayerRef, PackedQ15, PackedQ7,
    PackedWidth,
};
use fann_on_mcu::quantize;
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

fn random_narrow_layer(
    rng: &mut Rng,
    width: PackedWidth,
    n_in: usize,
    n_out: usize,
) -> (Vec<i32>, Vec<i32>) {
    let (lo, hi) = width.range();
    let span = (hi - lo + 1) as usize;
    let w: Vec<i32> = (0..n_in * n_out).map(|_| lo + rng.below(span) as i32).collect();
    let b: Vec<i32> = (0..n_out).map(|_| rng.below(20001) as i32 - 10000).collect();
    (w, b)
}

/// Run the packed kernel matching `width` (matvec or matmul).
fn run_packed(
    width: PackedWidth,
    dec: u32,
    layer: &PackedLayerRef,
    xs: &[i32],
    n_samples: usize,
    out: &mut [i32],
) {
    match width {
        PackedWidth::Q7 => PackedQ7::new(dec).matmul(layer, xs, n_samples, out),
        PackedWidth::Q15 => PackedQ15::new(dec).matmul(layer, xs, n_samples, out),
    }
}

#[test]
fn packed_bit_exact_vs_fixedq_randomized_shapes() {
    check("packed vs fixedq", 200, |rng| {
        // 1..=9 guarantees n_in < 4 and n_in % 4 != 0 cases appear
        // constantly; 1..=64 covers multi-panel rows.
        let n_in = rng.range_usize(1, 64);
        let n_out = rng.range_usize(1, 64);
        let n_samples = rng.range_usize(1, 9);
        let dec = rng.range_usize(2, 12) as u32;
        let width = if rng.below(2) == 0 { PackedWidth::Q7 } else { PackedWidth::Q15 };
        let (w, b) = random_narrow_layer(rng, width, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples)
            .map(|_| rng.below(200001) as i32 - 100000)
            .collect();

        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let mut want = vec![0i32; n_out * n_samples];
        FixedQ::new(dec).matmul(&layer, &xs, n_samples, &mut want);

        let panels = pack_rows(width, n_in, n_out, &w)
            .map_err(|e| format!("pack failed: {e}"))?;
        ensure(panels.unpack() == w, "pack/unpack round-trip")?;
        let pref = PackedLayerRef::new(&panels, &b);
        let mut got = vec![0i32; n_out * n_samples];
        run_packed(width, dec, &pref, &xs, n_samples, &mut got);
        ensure(
            got == want,
            format!("{width:?} n_in={n_in} n_out={n_out} n_samples={n_samples} dec={dec}"),
        )
    });
}

#[test]
fn packed_tiny_and_ragged_tails_exhaustive() {
    // Deterministic sweep over every n_in in 1..=9 (all < 4 and % 4
    // residues) × panel-straddling n_out values.
    let mut rng = Rng::new(0x7A11);
    for width in [PackedWidth::Q7, PackedWidth::Q15] {
        for n_in in 1..=9usize {
            for &n_out in &[1usize, 2, 3, 4, 5, 7, 8, 9] {
                let dec = 5;
                let (w, b) = random_narrow_layer(&mut rng, width, n_in, n_out);
                let x: Vec<i32> = (0..n_in).map(|_| rng.below(4001) as i32 - 2000).collect();
                let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                let mut want = vec![0i32; n_out];
                FixedQ::new(dec).matvec(&layer, &x, &mut want);
                let panels = pack_rows(width, n_in, n_out, &w).unwrap();
                let pref = PackedLayerRef::new(&panels, &b);
                let mut got = vec![0i32; n_out];
                match width {
                    PackedWidth::Q7 => PackedQ7::new(dec).matvec(&pref, &x, &mut got),
                    PackedWidth::Q15 => PackedQ15::new(dec).matvec(&pref, &x, &mut got),
                }
                assert_eq!(got, want, "{width:?} n_in={n_in} n_out={n_out}");
            }
        }
    }
}

#[test]
fn packed_slow_path_bit_exact_at_extreme_inputs() {
    // Inputs outside the narrow-multiply fast-path bound (|x| >= 2^24
    // for q7, 2^16 for q15) must fall back to exact i64 qmul and still
    // match FixedQ, including saturation rails.
    let mut rng = Rng::new(0xFA57);
    for width in [PackedWidth::Q7, PackedWidth::Q15] {
        let (n_in, n_out, n_samples) = (13, 6, 5);
        let dec = 3;
        let (w, b) = random_narrow_layer(&mut rng, width, n_in, n_out);
        let xs: Vec<i32> = (0..n_in * n_samples)
            .map(|i| match i % 4 {
                0 => i32::MAX - i as i32,
                1 => i32::MIN + i as i32,
                2 => (1 << 25) + i as i32,
                _ => rng.below(1000) as i32 - 500,
            })
            .collect();
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let mut want = vec![0i32; n_out * n_samples];
        FixedQ::new(dec).matmul(&layer, &xs, n_samples, &mut want);
        let panels = pack_rows(width, n_in, n_out, &w).unwrap();
        let pref = PackedLayerRef::new(&panels, &b);
        let mut got = vec![0i32; n_out * n_samples];
        run_packed(width, dec, &pref, &xs, n_samples, &mut got);
        assert_eq!(got, want, "{width:?}");
    }
}

#[test]
fn packed_bit_exact_under_every_forced_simd_level() {
    // The SIMD panel cores are storage-order rewrites of the scalar
    // fast path, never a change of arithmetic: pin every forcible
    // dispatch level (unavailable ISAs clamp to Scalar, so the sweep is
    // portable) bit-exact against FixedQ across ragged shapes and the
    // three input bands that steer path selection — extra-narrow
    // (|x| <= i16::MAX, engages the SSE2 madd core), mid-range
    // (engages the widening SIMD core on q7 but exceeds the q15
    // fast-path bound), and saturating extremes (exact i64 slow path).
    use fann_on_mcu::kernels::{with_forced_level, SimdLevel};

    let levels =
        [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2, SimdLevel::Neon];
    let mut rng = Rng::new(0x51D0);
    let n_samples = 3;
    for width in [PackedWidth::Q7, PackedWidth::Q15] {
        for n_in in [1usize, 3, 4, 5, 8, 9, 16, 31, 64, 67] {
            for n_out in [1usize, 2, 3, 4, 5, 8, 9] {
                for band in 0..3 {
                    let dec = 6;
                    let (w, b) = random_narrow_layer(&mut rng, width, n_in, n_out);
                    let xs: Vec<i32> = (0..n_in * n_samples)
                        .map(|i| match band {
                            0 => rng.below(2 * 32767 + 1) as i32 - 32767,
                            1 => rng.below(200001) as i32 - 100000,
                            _ => match i % 3 {
                                0 => i32::MAX - i as i32,
                                1 => i32::MIN + i as i32,
                                _ => rng.below(1000) as i32 - 500,
                            },
                        })
                        .collect();
                    let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                    let mut want_mv = vec![0i32; n_out];
                    FixedQ::new(dec).matvec(&layer, &xs[..n_in], &mut want_mv);
                    let mut want_mm = vec![0i32; n_out * n_samples];
                    FixedQ::new(dec).matmul(&layer, &xs, n_samples, &mut want_mm);

                    let panels = pack_rows(width, n_in, n_out, &w).unwrap();
                    let pref = PackedLayerRef::new(&panels, &b);
                    for level in levels {
                        let (got_mv, got_mm) = with_forced_level(level, || {
                            let mut mv = vec![0i32; n_out];
                            match width {
                                PackedWidth::Q7 => {
                                    PackedQ7::new(dec).matvec(&pref, &xs[..n_in], &mut mv)
                                }
                                PackedWidth::Q15 => {
                                    PackedQ15::new(dec).matvec(&pref, &xs[..n_in], &mut mv)
                                }
                            }
                            let mut mm = vec![0i32; n_out * n_samples];
                            run_packed(width, dec, &pref, &xs, n_samples, &mut mm);
                            (mv, mm)
                        });
                        assert_eq!(
                            got_mv, want_mv,
                            "{width:?} matvec {level:?} n_in={n_in} n_out={n_out} band={band}"
                        );
                        assert_eq!(
                            got_mm, want_mm,
                            "{width:?} matmul {level:?} n_in={n_in} n_out={n_out} band={band}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn packed_network_bit_exact_vs_fixed_reference_randomized() {
    check("packed network vs fixed", 40, |rng| {
        let n_layers = rng.range_usize(1, 3);
        let mut sizes = Vec::with_capacity(n_layers + 1);
        for _ in 0..=n_layers {
            sizes.push(rng.range_usize(1, 20));
        }
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)
            .map_err(|e| e.to_string())?;
        net.randomize(rng, None);
        let n_in = net.num_inputs();
        let n = rng.range_usize(1, 8);
        let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (fixed, packed) =
                from_float_packed(&net, 1.0, width).map_err(|e| e.to_string())?;
            ensure(
                fixed.decimal_point == packed.decimal_point,
                "decimal points agree",
            )?;
            let q = packed.quantize_input(&xs);
            ensure(
                packed.run_batch_q(&q, n) == fixed.run_batch_q(&q, n),
                format!("{width:?} sizes={sizes:?} n={n}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fused_epilogue_equals_unfused_for_every_f32_kernel() {
    check("fused == unfused (f32)", 80, |rng| {
        let n_in = rng.range_usize(1, 32);
        let n_out = rng.range_usize(1, 32);
        let n_samples = rng.range_usize(1, 9);
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let xs: Vec<f32> = (0..n_in * n_samples).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let steepness = rng.range_f32(0.25, 2.0);
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        for kernel in f32_kernels() {
            for act in ALL_ACTS {
                let mut fused = vec![0.0f32; n_out * n_samples];
                kernel.matmul_act(&layer, &xs, n_samples, &mut fused, act, steepness);
                let mut unfused = vec![0.0f32; n_out * n_samples];
                kernel.matmul(&layer, &xs, n_samples, &mut unfused);
                kernel.apply_epilogue(act, steepness, &mut unfused);
                ensure(
                    fused == unfused,
                    format!("{} matmul_act {act:?}", kernel.name()),
                )?;
                let x0 = &xs[..n_in];
                let mut fused1 = vec![0.0f32; n_out];
                kernel.matvec_act(&layer, x0, &mut fused1, act, steepness);
                let mut unfused1 = vec![0.0f32; n_out];
                kernel.matvec(&layer, x0, &mut unfused1);
                kernel.apply_epilogue(act, steepness, &mut unfused1);
                ensure(
                    fused1 == unfused1,
                    format!("{} matvec_act {act:?}", kernel.name()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn fused_epilogue_equals_unfused_for_q_kernels() {
    check("fused == unfused (q)", 60, |rng| {
        let n_in = rng.range_usize(1, 24);
        let n_out = rng.range_usize(1, 24);
        let n_samples = rng.range_usize(1, 6);
        let dec = rng.range_usize(3, 12) as u32;
        let (w, b) = {
            let (lo, hi) = PackedWidth::Q7.range();
            let span = (hi - lo + 1) as usize;
            let w: Vec<i32> = (0..n_in * n_out).map(|_| lo + rng.below(span) as i32).collect();
            let b: Vec<i32> = (0..n_out).map(|_| rng.below(2001) as i32 - 1000).collect();
            (w, b)
        };
        let xs: Vec<i32> =
            (0..n_in * n_samples).map(|_| rng.below(8193) as i32 - 4096).collect();
        let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
        let kernel = FixedQ::new(dec);
        for act in ALL_ACTS {
            let mut fused = vec![0i32; n_out * n_samples];
            kernel.matmul_act(&layer, &xs, n_samples, &mut fused, act, 1.0);
            let mut unfused = vec![0i32; n_out * n_samples];
            kernel.matmul(&layer, &xs, n_samples, &mut unfused);
            kernel.apply_epilogue(act, 1.0, &mut unfused);
            ensure(fused == unfused, format!("fixed_q {act:?}"))?;

            // Packed q7 fused epilogue against the same unfused values.
            let panels = pack_rows(PackedWidth::Q7, n_in, n_out, &w)
                .map_err(|e| e.to_string())?;
            let pref = PackedLayerRef::new(&panels, &b);
            let mut pfused = vec![0i32; n_out * n_samples];
            PackedQ7::new(dec).matmul_act(&pref, &xs, n_samples, &mut pfused, act);
            ensure(pfused == unfused, format!("packed_q7 {act:?}"))?;
        }
        Ok(())
    });
}

#[test]
fn packed_outputs_track_float_network() {
    // Sanity beyond bit-parity: the narrow quantization still computes
    // the right function (within step-linear activation tolerance).
    let mut rng = Rng::new(0xF10A7);
    let mut net = Network::new(&[8, 12, 4], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let (_, packed) = from_float_packed(&net, 1.0, PackedWidth::Q15).unwrap();
    for _ in 0..20 {
        let x: Vec<f32> = (0..8).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let yf = net.run(&x);
        let yq = packed.run(&x);
        for (a, b) in yf.iter().zip(&yq) {
            assert!((a - b).abs() < 0.08, "float {a} vs packed {b}");
        }
    }
}

#[test]
fn fixed_network_forward_unchanged_by_fusion_refactor() {
    // The fused routing must not change FixedNetwork numerics: compare
    // against the longhand quantize::dense_q_into path layer by layer.
    let mut rng = Rng::new(0xD00D);
    let mut net = Network::new(&[6, 9, 3], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let x: Vec<f32> = (0..6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let xq = fixed.quantize_input(&x);
    let got = fixed.run_q(&xq);

    let mut cur = xq;
    for layer in &fixed.layers {
        let mut next = vec![0i32; layer.n_out];
        quantize::dense_q_into(
            &cur,
            &layer.weights,
            &layer.biases,
            fixed.decimal_point,
            layer.activation,
            &mut next,
        );
        cur = next;
    }
    assert_eq!(got, cur);
}
