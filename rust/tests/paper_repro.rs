//! Paper-reproduction suite tests: dataset generator contracts, the
//! per-app quick pipeline (trained → quantized → emitted → emulated
//! agrees with the host paths), and the `paper reproduce` driver's
//! `PAPER_RESULTS.json` / `RESULTS.md` outputs with their headline
//! fields — the integration gate behind the ISSUE's acceptance
//! criterion (CI additionally runs the CLI form `paper reproduce
//! --quick` and asserts the same fields from the shell).

use fann_on_mcu::apps::paper::{train_paper_app, PAPER_APPS, PAPER_MAX_ABS_INPUT};
use fann_on_mcu::bench::paper::{paper_targets, reproduce, write_results, ReproduceOptions};
use fann_on_mcu::codegen;
use fann_on_mcu::datasets::wearable;
use fann_on_mcu::emulator;
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::predict_class;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fann_paper_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn wearable_generators_are_deterministic_and_balanced() {
    // Determinism under a fixed seed, across the full generator set.
    for gen in [wearable::emg, wearable::ecg, wearable::eeg] {
        let a = gen(123);
        let b = gen(123);
        assert_eq!(a.inputs, b.inputs);
        assert_eq!(a.targets, b.targets);
        assert_ne!(a.inputs, gen(124).inputs);
    }
    // Class balance sanity: every class holds exactly its share.
    let d = wearable::emg(9);
    let per_class = d.len() / wearable::EMG_CLASSES;
    for c in 0..wearable::EMG_CLASSES {
        assert_eq!((0..d.len()).filter(|&i| d.label(i) == c).count(), per_class);
    }
    let d = wearable::ecg(9);
    for c in 0..wearable::ECG_CLASSES {
        assert_eq!(
            (0..d.len()).filter(|&i| d.label(i) == c).count(),
            d.len() / wearable::ECG_CLASSES
        );
    }
    let d = wearable::eeg(9);
    assert_eq!(
        (0..d.len()).filter(|&i| d.label(i) == 1).count() * 2,
        d.len()
    );
}

#[test]
fn sized_variants_scale_without_changing_shape() {
    let small = wearable::emg_sized(5, 10);
    assert_eq!(small.len(), 10 * wearable::EMG_CLASSES);
    assert_eq!(small.num_inputs, wearable::EMG_CHANNELS * wearable::EMG_WINDOW);
    let small = wearable::ecg_sized(5, 12);
    assert_eq!(small.len(), 12 * wearable::ECG_CLASSES);
    let small = wearable::eeg_sized(5, 14);
    assert_eq!((small.len(), small.num_outputs), (28, 1));
}

/// Small-epoch smoke run per app: the trained → quantized → emitted →
/// emulated chain must (a) be bit-exact between the emulated artifact
/// and the host quantized network, and (b) classify in agreement with
/// the host float path on a strong majority of held-out samples.
#[test]
fn quick_pipeline_emulated_predictions_agree_with_host() {
    for spec in PAPER_APPS {
        let pipe = train_paper_app(spec, 7, true).unwrap();
        let bundle = codegen::emit_float(
            &pipe.net,
            Target::WolfCluster { cores: 8 },
            pipe.repr,
            PAPER_MAX_ABS_INPUT,
        )
        .unwrap();

        let n = 12.min(pipe.test.len());
        let mut agree_float = 0usize;
        for i in 0..n {
            let x = pipe.test.input(i);
            let report = emulator::emulate(&bundle.artifact, x).unwrap();
            // Bit-exact vs the host quantized path (same invariant
            // `deploy emulate` enforces).
            assert_eq!(
                report.outputs,
                pipe.fixed.run(x),
                "{}: emulated vs host quantized, sample {i}",
                spec.name
            );
            if predict_class(&report.outputs) == predict_class(&pipe.net.run(x)) {
                agree_float += 1;
            }
        }
        assert!(
            agree_float * 10 >= n * 8,
            "{}: emulated agreed with the float path on only {agree_float}/{n} samples",
            spec.name
        );
    }
}

#[test]
fn reproduce_quick_produces_sane_headline_and_files() {
    let results = reproduce(ReproduceOptions { seed: 7, quick: true }).unwrap();

    // Shape: every app swept over every target, in registry order.
    assert_eq!(results.apps.len(), PAPER_APPS.len());
    for (a, spec) in results.apps.iter().zip(PAPER_APPS) {
        assert_eq!(a.pipeline.spec.name, spec.name);
        assert_eq!(a.rows.len(), paper_targets().len());
        for r in &a.rows {
            assert!(r.cycles > 0.0, "{}: no cycles on {}", spec.name, r.target.slug());
            assert!(r.energy_uj > 0.0);
            assert!(r.param_bytes > 0 && r.budget_bytes > 0);
            assert!(
                r.est_memory_bytes <= r.budget_bytes,
                "{} does not fit {} yet region={}",
                spec.name,
                r.target.slug(),
                r.region.name()
            );
        }
        // Per-app headline fields are finite and the cluster scaling
        // curve is monotone-ish: 8 cores beat 1 core.
        assert!(a.speedup_wolf8_vs_m4.is_finite());
        let s8 = a
            .cluster_scaling
            .iter()
            .find(|&&(c, _, _)| c == 8)
            .map(|&(_, s, _)| s)
            .unwrap();
        assert!(s8 > 1.0, "{}: 8-core cluster speedup {s8} <= 1", spec.name);
    }

    // The ISSUE's acceptance gate: headline fields present and sane.
    assert!(
        results.speedup_wolf8_vs_m4 > 1.0,
        "speedup_wolf8_vs_m4 {}",
        results.speedup_wolf8_vs_m4
    );
    assert!(
        results.energy_reduction_wolf8_vs_m4 > 0.0
            && results.energy_reduction_wolf8_vs_m4 < 1.0,
        "energy_reduction_wolf8_vs_m4 {}",
        results.energy_reduction_wolf8_vs_m4
    );

    // Written artifacts contain the machine-readable fields.
    let dir = tmpdir("results");
    let (json_path, md_path) = write_results(&results, &dir).unwrap();
    let json = std::fs::read_to_string(&json_path).unwrap();
    for needle in [
        "\"schema\": \"fann-on-mcu/paper-results/v1\"",
        "\"speedup_wolf8_vs_m4\"",
        "\"energy_reduction_wolf8_vs_m4\"",
        "\"latency_cycles\"",
        "\"memory_budget_bytes\"",
        "\"energy_uj_per_classification\"",
        "\"cluster_scaling\"",
        "\"name\": \"emg\"",
        "\"name\": \"ecg\"",
        "\"name\": \"eeg\"",
        "\"target\": \"cortex-m4f\"",
        "\"target\": \"wolf-8core\"",
    ] {
        assert!(json.contains(needle), "PAPER_RESULTS.json missing {needle}");
    }
    let md = std::fs::read_to_string(&md_path).unwrap();
    assert!(md.contains("# Paper-reproduction results"));
    assert!(md.contains("wolf-8core"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The EMG flagship app must exercise the DMA-streaming cluster path
/// (its Eq. 2 footprint exceeds the L1 budget), so the reproduction
/// covers both cluster placements: L1-resident (ECG/EEG) and
/// L2-resident with DMA (EMG).
#[test]
fn emg_streams_from_l2_while_small_apps_sit_in_l1() {
    let pipe_emg = train_paper_app(PAPER_APPS[0], 3, true).unwrap();
    let b = codegen::emit_float(
        &pipe_emg.net,
        Target::WolfCluster { cores: 8 },
        pipe_emg.repr,
        PAPER_MAX_ABS_INPUT,
    )
    .unwrap();
    assert!(b.artifact.plan.dma.is_some(), "EMG should DMA-stream");

    let pipe_eeg = train_paper_app(PAPER_APPS[2], 3, true).unwrap();
    let b = codegen::emit_float(
        &pipe_eeg.net,
        Target::WolfCluster { cores: 8 },
        pipe_eeg.repr,
        PAPER_MAX_ABS_INPUT,
    )
    .unwrap();
    assert!(b.artifact.plan.dma.is_none(), "EEG should be L1-resident");
}
