//! Integration tests for the PJRT runtime: load the AOT artifacts, run
//! forward passes and the training step, verify the Rust↔JAX contract.
//!
//! Requires `make artifacts` (skipped with a message otherwise) and the
//! `pjrt` feature built against the real `xla` crate (the whole file is
//! compiled out of the default offline build).
#![cfg(feature = "pjrt")]

use fann_on_mcu::fann::TrainData;
use fann_on_mcu::runtime::{ArtifactDir, PjrtTrainer, Runtime};
use fann_on_mcu::util::rng::Rng;

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::locate(None) {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifests_match_app_registry() {
    let Some(art) = artifacts() else { return };
    for (name, sizes) in [
        ("gesture", fann_on_mcu::apps::GESTURE.sizes),
        ("fall", fann_on_mcu::apps::FALL.sizes),
        ("activity", fann_on_mcu::apps::ACTIVITY.sizes),
        ("example", fann_on_mcu::apps::EXAMPLE.sizes),
    ] {
        let m = art.manifest(name).unwrap();
        assert_eq!(m.layer_sizes(), sizes, "{name}");
    }
}

#[test]
fn forward_executable_runs_and_is_bounded() {
    let Some(art) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let trainer = PjrtTrainer::new(&rt, &art, "xor", 11).unwrap();
    let out = trainer.forward1(&[1.0, 0.0]).unwrap();
    assert_eq!(out.len(), 1);
    // sigmoid output
    assert!((0.0..=1.0).contains(&out[0]));
}

#[test]
fn training_step_reduces_loss_on_xor() {
    let Some(art) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut trainer = PjrtTrainer::new(&rt, &art, "xor", 42).unwrap();
    let data = fann_on_mcu::datasets::xor();
    let mut rng = Rng::new(7);
    let curve = trainer.train(&data, 400, &mut rng).unwrap();
    let first = curve[0];
    let last = *curve.last().unwrap();
    assert!(
        last < first * 0.5 && last < 0.1,
        "loss did not drop: {first} -> {last}"
    );
    // The trained net must actually solve xor.
    for (x, want) in [
        ([0.0f32, 0.0], false),
        ([0.0, 1.0], true),
        ([1.0, 0.0], true),
        ([1.0, 1.0], false),
    ] {
        let y = trainer.forward1(&x).unwrap()[0];
        assert_eq!(y >= 0.5, want, "x={x:?} y={y}");
    }
}

#[test]
fn exported_network_matches_pjrt_forward() {
    // The to_network() export (JAX (in,out) -> FANN row-major) must
    // produce identical outputs through the native Rust path.
    let Some(art) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut trainer = PjrtTrainer::new(&rt, &art, "activity", 5).unwrap();
    let data = fann_on_mcu::datasets::activity(5);
    let mut rng = Rng::new(8);
    trainer.train(&data, 30, &mut rng).unwrap();

    let net = trainer.to_network().unwrap();
    let mut max_diff = 0.0f32;
    for i in 0..20 {
        let x = data.input(i);
        let pjrt = trainer.forward1(x).unwrap();
        let native = net.run(x);
        for (a, b) in pjrt.iter().zip(&native) {
            max_diff = max_diff.max((a - b).abs());
        }
    }
    assert!(max_diff < 2e-5, "PJRT vs native forward diff {max_diff}");
}

#[test]
fn pjrt_accuracy_matches_native_accuracy() {
    let Some(art) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut trainer = PjrtTrainer::new(&rt, &art, "activity", 9).unwrap();
    let mut data = fann_on_mcu::datasets::activity(9);
    data.normalize_inputs();
    let mut rng = Rng::new(10);
    trainer.train(&data, 600, &mut rng).unwrap();

    let acc_pjrt = trainer.accuracy(&data).unwrap();
    let net = trainer.to_network().unwrap();
    let acc_native = fann_on_mcu::fann::train::accuracy(&net, &data);
    assert!(
        (acc_pjrt - acc_native).abs() < 0.01,
        "pjrt {acc_pjrt} vs native {acc_native}"
    );
    assert!(acc_pjrt > 0.5, "training made no progress: {acc_pjrt}");
}

#[test]
fn trainer_rejects_mismatched_data() {
    let Some(art) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let mut trainer = PjrtTrainer::new(&rt, &art, "xor", 1).unwrap();
    let bad = TrainData::new(3, 1);
    let mut rng = Rng::new(1);
    assert!(trainer.train(&bad, 1, &mut rng).is_err());
}
