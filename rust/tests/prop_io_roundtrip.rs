//! Round-trip + fuzz properties for the FANN `.net` file formats:
//! reading back a written file reproduces the same network (bitwise
//! parameters, same decimal-point metadata), and malformed inputs —
//! random truncation, NaN/inf parameters, bad layer counts, short
//! activation lines, out-of-range decimal points — produce structured
//! errors, never panics.

use fann_on_mcu::fann::activation::ALL as ALL_ACTS;
use fann_on_mcu::fann::{io, Activation, FixedNetwork, Network};
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

fn random_net(rng: &mut Rng) -> Network {
    let n_layers = rng.range_usize(2, 5);
    let sizes: Vec<usize> = (0..n_layers).map(|_| rng.range_usize(1, 9)).collect();
    let hidden = ALL_ACTS[rng.below(ALL_ACTS.len())];
    let output = ALL_ACTS[rng.below(ALL_ACTS.len())];
    let mut net = Network::new(&sizes, hidden, output).unwrap();
    net.randomize(rng, None);
    for layer in &mut net.layers {
        layer.steepness = rng.range_f32(0.25, 2.0);
    }
    net
}

#[test]
fn float_roundtrip_is_bitwise_identical() {
    check("float .net round-trip", 120, |rng| {
        let net = random_net(rng);
        let text = io::save_float(&net);
        let back = io::load_float(&text).map_err(|e| e.to_string())?;
        ensure(back.layers.len() == net.layers.len(), "layer count changed")?;
        for (i, (a, b)) in net.layers.iter().zip(&back.layers).enumerate() {
            ensure(a.n_in == b.n_in && a.n_out == b.n_out, format!("layer {i} shape"))?;
            ensure(a.weights == b.weights, format!("layer {i} weights not bitwise equal"))?;
            ensure(a.biases == b.biases, format!("layer {i} biases not bitwise equal"))?;
            ensure(a.activation == b.activation, format!("layer {i} activation"))?;
            ensure(a.steepness == b.steepness, format!("layer {i} steepness"))?;
        }
        // And therefore identical outputs.
        let x: Vec<f32> = (0..net.num_inputs()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        ensure(net.run(&x) == back.run(&x), "outputs diverged after round-trip")
    });
}

#[test]
fn fixed_roundtrip_preserves_decimal_point_and_params() {
    check("fixed .net round-trip", 120, |rng| {
        let net = random_net(rng);
        let fixed = FixedNetwork::from_float(&net, 1.0).map_err(|e| e.to_string())?;
        let text = io::save_fixed(&fixed);
        let back = io::load_fixed(&text).map_err(|e| e.to_string())?;
        ensure(
            back.decimal_point == fixed.decimal_point,
            format!(
                "decimal point changed: {} -> {}",
                fixed.decimal_point, back.decimal_point
            ),
        )?;
        for (i, (a, b)) in fixed.layers.iter().zip(&back.layers).enumerate() {
            ensure(a.weights == b.weights, format!("layer {i} weights"))?;
            ensure(a.biases == b.biases, format!("layer {i} biases"))?;
            ensure(a.activation == b.activation, format!("layer {i} activation"))?;
        }
        let xq: Vec<i32> = (0..fixed.num_inputs()).map(|_| rng.below(2048) as i32 - 1024).collect();
        ensure(fixed.run_q(&xq) == back.run_q(&xq), "Q outputs diverged after round-trip")
    });
}

#[test]
fn random_truncation_never_panics() {
    check("truncation fuzz", 200, |rng| {
        let net = random_net(rng);
        let text = if rng.below(2) == 0 {
            io::save_float(&net)
        } else {
            io::save_fixed(&FixedNetwork::from_float(&net, 1.0).map_err(|e| e.to_string())?)
        };
        // Chop at a random byte (the formats are pure ASCII, so every
        // index is a char boundary) — the loaders must return, not
        // panic. A longer prefix may still parse if the chop lands
        // exactly at the end; anything else must be a clean Err.
        let cut = rng.below(text.len().max(1));
        let prefix = &text[..cut];
        let _ = io::load_float(prefix);
        let _ = io::load_fixed(prefix);
        Ok(())
    });
}

#[test]
fn corrupted_fields_are_errors_not_panics() {
    check("field corruption fuzz", 150, |rng| {
        let net = random_net(rng);
        let fixed = FixedNetwork::from_float(&net, 1.0).map_err(|e| e.to_string())?;
        let float_text = io::save_float(&net);
        let fixed_text = io::save_fixed(&fixed);

        // A grab-bag of malformed variants; each must load as Err.
        let cases: Vec<String> = vec![
            float_text.replacen("weights=", "weights=NaN ", 1),
            float_text.replacen("steepness=", "steepness=inf ", 1),
            float_text.replacen("num_layers=", "num_layers=1\nbogus=", 1),
            float_text.replacen("layer_sizes=", "layer_sizes=0 ", 1),
            float_text.replacen("activations=", "activations=softmax ", 1),
            fixed_text.replacen("decimal_point=", "decimal_point=9", 1),
            fixed_text.replacen("activations=", "activations=tanh\nweights=", 1),
            fixed_text.replacen("weights=", "weights=notanumber ", 1),
        ];
        for (i, case) in cases.iter().enumerate() {
            let res = if case.starts_with("FANN_FLO") {
                io::load_float(case).map(|_| ())
            } else {
                io::load_fixed(case).map(|_| ())
            };
            ensure(res.is_err(), format!("corrupt case {i} unexpectedly parsed"))?;
        }
        Ok(())
    });
}

#[test]
fn trained_pipeline_survives_roundtrip() {
    // The end-to-end file contract: save → load → quantized outputs
    // bit-equal, which is what `deploy --net file.net` relies on.
    let mut rng = Rng::new(0xD15C);
    let mut net = Network::new(&[4, 6, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();

    let f2 = io::load_float(&io::save_float(&net)).unwrap();
    let q2 = io::load_fixed(&io::save_fixed(&fixed)).unwrap();
    let x = [0.3f32, -0.1, 0.8, -0.9];
    assert_eq!(net.run(&x), f2.run(&x));
    let xq = fixed.quantize_input(&x);
    assert_eq!(fixed.run_q(&xq), q2.run_q(&xq));
    assert_eq!(fixed.decimal_point, q2.decimal_point);
}
