//! Cross-layer numeric parity: the Rust-native inference paths must match
//! the Pallas kernels bit-for-bit (fixed) / within float tolerance,
//! via the parity vectors `aot.py` emits into `artifacts/`.
//!
//! Requires `make artifacts` (skipped otherwise).

use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::fann::fixed::FixedLayer;
use fann_on_mcu::fann::net::Layer;
use fann_on_mcu::runtime::ArtifactDir;
use fann_on_mcu::util::tsv::{parse_parity, ParityCase};

fn artifacts() -> Option<ArtifactDir> {
    match ArtifactDir::locate(None) {
        Ok(a) => Some(a),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

fn load_cases(art: &ArtifactDir, which: &str) -> Vec<ParityCase> {
    let text = std::fs::read_to_string(art.parity_file(which)).unwrap();
    let cases = parse_parity(&text).unwrap();
    assert_eq!(cases.len(), 5, "expected one case per topology");
    cases
}

/// Build a float Network from a parity case. JAX weights are (in, out);
/// FANN rows are per output neuron.
fn network_from_case(case: &ParityCase) -> Network {
    let mut layers = Vec::new();
    let n_layers = case.num_layers();
    for l in 0..n_layers {
        let w = case.tensor(&format!("w{l}")).unwrap();
        let b = case.tensor(&format!("b{l}")).unwrap();
        let (n_in, n_out) = (w.shape[0], w.shape[1]);
        let wf = w.as_f32();
        let mut weights = vec![0.0f32; n_in * n_out];
        for i in 0..n_in {
            for o in 0..n_out {
                weights[o * n_in + i] = wf[i * n_out + o];
            }
        }
        let act = if l == n_layers - 1 {
            &case.output_act
        } else {
            &case.hidden_act
        };
        layers.push(Layer {
            n_in,
            n_out,
            weights,
            biases: b.as_f32(),
            activation: Activation::parse(act).unwrap(),
            steepness: 1.0,
        });
    }
    Network { layers }
}

fn fixed_network_from_case(case: &ParityCase) -> FixedNetwork {
    let mut layers = Vec::new();
    let n_layers = case.num_layers();
    for l in 0..n_layers {
        let w = case.tensor(&format!("w{l}")).unwrap();
        let b = case.tensor(&format!("b{l}")).unwrap();
        let (n_in, n_out) = (w.shape[0], w.shape[1]);
        let wi = w.as_i32();
        let mut weights = vec![0i32; n_in * n_out];
        for i in 0..n_in {
            for o in 0..n_out {
                weights[o * n_in + i] = wi[i * n_out + o];
            }
        }
        let act = if l == n_layers - 1 {
            &case.output_act
        } else {
            &case.hidden_act
        };
        layers.push(FixedLayer {
            n_in,
            n_out,
            weights,
            biases: b.as_i32(),
            activation: Activation::parse(act).unwrap(),
        });
    }
    FixedNetwork {
        layers,
        decimal_point: case.dec.unwrap(),
    }
}

#[test]
fn float_forward_matches_pallas() {
    let Some(art) = artifacts() else { return };
    for case in load_cases(&art, "float") {
        let net = network_from_case(&case);
        let x = case.tensor("x").unwrap();
        let want = case.tensor("out").unwrap();
        let (batch, n_in) = (x.shape[0], x.shape[1]);
        let n_out = want.shape[1];
        let xf = x.as_f32();
        let wf = want.as_f32();
        for s in 0..batch {
            let got = net.run(&xf[s * n_in..(s + 1) * n_in]);
            for (o, g) in got.iter().enumerate() {
                let w = wf[s * n_out + o];
                assert!(
                    (g - w).abs() < 3e-5,
                    "{}: sample {s} out {o}: rust {g} pallas {w}",
                    case.name
                );
            }
        }
    }
}

#[test]
fn fixed_forward_bit_exact_with_pallas() {
    let Some(art) = artifacts() else { return };
    for case in load_cases(&art, "fixed") {
        let net = fixed_network_from_case(&case);
        let x = case.tensor("x").unwrap();
        let want = case.tensor("out").unwrap();
        let (batch, n_in) = (x.shape[0], x.shape[1]);
        let n_out = want.shape[1];
        let xi = x.as_i32();
        let wi = want.as_i64();
        for s in 0..batch {
            let got = net.run_q(&xi[s * n_in..(s + 1) * n_in]);
            for (o, g) in got.iter().enumerate() {
                let w = wi[s * n_out + o];
                assert_eq!(
                    *g as i64, w,
                    "{}: sample {s} out {o}: rust {g} pallas {w}",
                    case.name
                );
            }
        }
    }
}

#[test]
fn parity_covers_all_topologies() {
    let Some(art) = artifacts() else { return };
    let float_names: Vec<String> = load_cases(&art, "float")
        .into_iter()
        .map(|c| c.name)
        .collect();
    for name in ["xor", "example", "gesture", "fall", "activity"] {
        assert!(float_names.iter().any(|n| n == name), "missing {name}");
    }
}
