//! Integration tests over the deployment pipeline: train → quantize →
//! plan → codegen → simulate, across targets.

use fann_on_mcu::codegen::{self, NetSource};
use fann_on_mcu::deploy::{self, DmaStrategy, NetShape};
use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::simulator::{self, CostOptions, Executable};
use fann_on_mcu::targets::{Chip, DataType, Region, Target};
use fann_on_mcu::util::rng::Rng;

fn trained_like(sizes: &[usize], seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    net
}

#[test]
fn full_pipeline_float_m4() {
    let net = trained_like(&[5, 100, 100, 3], 1);
    let shape = NetShape::from(&net);
    let plan = deploy::plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Float32).unwrap();
    assert_eq!(plan.region, Region::Ram);

    // codegen emits a complete bundle
    let code = codegen::generate(&plan, NetSource::Float(&net));
    assert!(code.file("fann_conf.h").is_some());
    assert!(code.file("fann_net.h").unwrap().contains("fann_weights_2"));

    // simulate produces outputs + timing
    let x = [0.1f32, 0.2, -0.3, 0.4, -0.5];
    let r = simulator::simulate(&plan, &Executable::Float(&net), &x, CostOptions::default()).unwrap();
    assert_eq!(r.outputs.len(), 3);
    assert!(r.seconds > 0.0 && r.energy_uj > 0.0);
    assert_eq!(r.outputs, net.run(&x));
}

#[test]
fn full_pipeline_fixed_wolf_fc() {
    let net = trained_like(&[10, 16, 4], 2);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let shape = NetShape::from(&fixed);
    let plan = deploy::plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
    assert_eq!(plan.region, Region::PrivateL2);

    let code = codegen::generate(&plan, NetSource::Fixed(&fixed));
    assert!(code
        .file("fann_conf.h")
        .unwrap()
        .contains(&format!("FANN_FIXED_DECIMAL_POINT {}", fixed.decimal_point)));

    let x = vec![0.05f32; 10];
    let r = simulator::simulate(&plan, &Executable::Fixed(&fixed), &x, CostOptions::default()).unwrap();
    assert_eq!(r.outputs.len(), 4);
}

#[test]
fn dma_strategies_change_with_network_scale() {
    // Growing the Fig. 11 family crosses L1 -> layer-wise -> neuron-wise,
    // matching the paper's 12 / 21 hidden-layer boundaries.
    let mut regimes = Vec::new();
    for l in [4, 16, 23] {
        let shape = fann_on_mcu::bench::fig11_shape(l, 8);
        let plan = deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Fixed).unwrap();
        regimes.push((plan.region, plan.dma));
    }
    assert_eq!(regimes[0], (Region::L1, None));
    assert_eq!(regimes[1], (Region::SharedL2, Some(DmaStrategy::LayerWise)));
    assert_eq!(regimes[2], (Region::SharedL2, Some(DmaStrategy::NeuronWise)));
}

#[test]
fn more_cores_never_slower_for_big_nets() {
    let net = trained_like(&[76, 300, 200, 100, 10], 3);
    let shape = NetShape::from(&net);
    let x = vec![0.1f32; 76];
    let mut prev = f64::INFINITY;
    for cores in [1u32, 2, 4, 8] {
        let plan = deploy::plan(&shape, Target::WolfCluster { cores }, DataType::Float32).unwrap();
        let r = simulator::simulate(&plan, &Executable::Float(&net), &x, CostOptions::default())
            .unwrap();
        assert!(
            r.seconds < prev,
            "{cores} cores: {} not faster than {prev}",
            r.seconds
        );
        prev = r.seconds;
    }
}

#[test]
fn quantization_plus_deployment_preserves_decisions() {
    // Train a real classifier, quantize, deploy to every Table II
    // target: argmax decisions agree with float on >90% of samples.
    let app = fann_on_mcu::apps::train_app(&fann_on_mcu::apps::ACTIVITY, 11).unwrap();
    let data = fann_on_mcu::apps::ACTIVITY.dataset(11);
    let mut agree = 0;
    let n = 100.min(data.len());
    for i in 0..n {
        let x = data.input(i);
        let f = fann_on_mcu::util::argmax(&app.net.run(x));
        let q = fann_on_mcu::util::argmax(&app.fixed.run(x));
        if f == q {
            agree += 1;
        }
    }
    assert!(agree >= 90, "only {agree}/{n} decisions agree after quantization");
}

#[test]
fn generated_code_reflects_placement() {
    // App A on the cluster must emit the neuron-wise DMA loop; the same
    // net on the M4 must emit flash placement.
    let net = trained_like(&[76, 300, 200, 100, 10], 4);
    let shape = NetShape::from(&net);

    let p = deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
    let g = codegen::generate(&p, NetSource::Float(&net));
    assert!(g.file("fann_dma.c").unwrap().contains("fann_prefetch_row"));

    let p = deploy::plan(&shape, Target::CortexM4(Chip::Nrf52832), DataType::Float32).unwrap();
    let g = codegen::generate(&p, NetSource::Float(&net));
    assert!(g.file("fann_conf.h").unwrap().contains("flash"));
}
