//! Property test for the fault-tolerance layer: over randomized seeded
//! fault schedules (injected exec-panic windows that trip and then
//! release the per-model quarantine breaker), randomized batching
//! policies (size and deadline flush triggers, shed-at-capacity,
//! optional per-request deadline budgets), randomized pump/advance
//! interleavings and randomized shutdown timing (mid-run
//! `fail_pending`, end-of-run drain), every **accepted** request
//! receives **exactly one** terminal reply — never zero (lost), never
//! two (duplicate) — and the service's own counters reconcile with
//! what the client-side channel saw. The shard count is itself
//! randomized over {1, 2, 4}, so the invariant is exercised both with
//! all models on one dispatcher shard and spread across several.
//! Everything runs in manual mode on a virtual clock, so the whole
//! admit/flush/timeout/quarantine timeline is deterministic per seed
//! and needs no sleeps.
//!
//! A second property drives the same exactly-one-terminal-reply
//! contract **across the wire front-end**: randomized request mixes
//! (valid, NaN-poisoned, wrong-width, unknown-model) over a UDS
//! socket against a started service, asserting one typed response
//! frame per request id and reconciled wire/service counters.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::ExecPlan;
use fann_on_mcu::service::frame::ResponseBody;
use fann_on_mcu::service::wire::temp_uds_path;
use fann_on_mcu::service::{
    BatchPolicy, BreakerPolicy, FaultPlan, InferenceService, ModelRegistry, RequestFrame,
    ShardPolicy, SubmitError, WireClient, WireConfig, WireServer,
};
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

/// One f32 model and one fixed-point model, so both the finiteness
/// check (f32 rejects NaN at submit) and the quantize-at-submit path
/// (Q saturates, immune to poison) stay under test.
const MODELS: [&str; 2] = ["pf", "pq"];

fn registry(rng: &mut Rng, breaker: BreakerPolicy) -> Arc<ModelRegistry> {
    let reg = Arc::new(ModelRegistry::with_breaker(breaker));
    let mut net = Network::new(&[3, 5, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(rng, None);
    reg.register("pf", &net).unwrap();
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    reg.register_plan("pq", ExecPlan::compile(&fixed)).unwrap();
    reg
}

#[test]
fn every_accepted_request_gets_exactly_one_terminal_reply() {
    check("exactly-one-terminal-reply", 60, |rng| {
        // Randomized policy: tiny batches and capacities so size
        // triggers, deadline triggers and sheds all fire often.
        let mut policy = BatchPolicy {
            max_batch: rng.range_usize(1, 4),
            max_delay: Duration::from_micros(rng.range_usize(50, 2000) as u64),
            queue_capacity: rng.range_usize(2, 8),
            request_budget: if rng.below(3) == 0 {
                None
            } else {
                Some(Duration::from_micros(rng.range_usize(100, 3000) as u64))
            },
            ..BatchPolicy::default()
        };
        if rng.below(3) == 0 {
            policy.exec_workers = 2;
        }
        let breaker = BreakerPolicy {
            failure_threshold: rng.range_usize(1, 3) as u32,
            cooldown: Duration::from_micros(rng.range_usize(200, 2000) as u64),
        };
        // Randomized fault schedule: a panic window (possibly empty)
        // over one model's execution-attempt sequence. No latency
        // spikes (they sleep for real) and no dispatcher kills (manual
        // mode has no dispatcher) — those live in the chaos harness.
        let from = rng.below(4) as u64;
        let plan = FaultPlan {
            seed: rng.next_u64(),
            panic_model: MODELS[rng.below(2)].to_string(),
            panic_from: from,
            panic_until: from + rng.below(5) as u64,
            ..FaultPlan::default()
        };

        let reg = registry(rng, breaker);
        // Randomized shard count: the exactly-one-reply contract may
        // not depend on how models map onto dispatcher shards.
        let shards = [1usize, 2, 4][rng.below(3)];
        let svc = InferenceService::new_sharded(
            Arc::clone(&reg),
            &policy,
            &ShardPolicy::new(shards),
            Some(plan),
        );
        ensure(
            svc.shard_count() == shards,
            "service must honor the requested shard count",
        )?;
        let (tx, rx) = mpsc::channel();
        let t0 = Instant::now();
        let mut offset_us: u64 = 0;
        let mut accepted: HashMap<u64, &str> = HashMap::new();

        let events = rng.range_usize(8, 40);
        for _ in 0..events {
            offset_us += rng.below(1500) as u64;
            let now = t0 + Duration::from_micros(offset_us);
            match rng.below(10) {
                0..=5 => {
                    let model = MODELS[rng.below(2)];
                    let tenant = rng.below(3) as u64;
                    let mut input = [0.0f32; 3];
                    for v in &mut input {
                        *v = rng.range_f32(-1.0, 1.0);
                    }
                    if model == "pf" && rng.below(8) == 0 {
                        // Poisoned submit: rejected synchronously, no
                        // ticket, no queued trace.
                        let i = rng.below(3);
                        input[i] = f32::NAN;
                        ensure(
                            svc.submit_at(model, tenant, &input, &tx, now)
                                == Err(SubmitError::BadInput { index: i }),
                            "NaN input must be rejected at submit",
                        )?;
                        continue;
                    }
                    match svc.submit_at(model, tenant, &input, &tx, now) {
                        Ok(ticket) => {
                            ensure(
                                accepted.insert(ticket, model).is_none(),
                                "ticket numbers must be unique",
                            )?;
                        }
                        // Backpressure and quarantine are synchronous
                        // rejections: nothing queued, nothing owed.
                        Err(SubmitError::QueueFull { .. })
                        | Err(SubmitError::Quarantined { .. }) => {}
                        Err(e) => return Err(format!("unexpected submit rejection: {e}")),
                    }
                }
                6 | 7 => {
                    svc.pump_at(now);
                }
                8 => {
                    svc.fail_pending("prop: injected mid-run failure");
                }
                _ => {
                    // Jump the clock far enough to expire every
                    // deadline trigger and request budget, then pump:
                    // timeouts must be terminal replies too.
                    offset_us += 10_000;
                    svc.pump_at(t0 + Duration::from_micros(offset_us));
                }
            }
        }

        // Randomized shutdown timing; manual-mode shutdown drains
        // whatever is still queued, so nothing may leak.
        match rng.below(3) {
            0 => {
                svc.fail_pending("prop: failed at shutdown");
            }
            1 => {
                svc.pump_at(t0 + Duration::from_micros(offset_us));
            }
            _ => {}
        }
        let snap = svc.shutdown();

        // The invariant: exactly one terminal reply per accepted
        // ticket. All senders are gone, so try_iter sees everything.
        drop(tx);
        let mut seen: HashMap<u64, u32> = HashMap::new();
        for r in rx.try_iter() {
            *seen.entry(r.ticket).or_insert(0) += 1;
            ensure(
                accepted.contains_key(&r.ticket),
                format!("reply for ticket {} that was never accepted", r.ticket),
            )?;
        }
        ensure(
            seen.values().all(|&c| c == 1),
            "some ticket received more than one terminal reply",
        )?;
        ensure(
            seen.len() == accepted.len(),
            format!("lost replies: accepted {} but saw {}", accepted.len(), seen.len()),
        )?;
        // And the service's books agree with the channel.
        ensure(
            snap.total_requests() == accepted.len() as u64,
            "accepted-request counter diverged from client view",
        )?;
        ensure(
            snap.total_completed() + snap.total_failed() == accepted.len() as u64,
            format!(
                "counters leak: completed {} + failed {} != accepted {}",
                snap.total_completed(),
                snap.total_failed(),
                accepted.len()
            ),
        )?;
        // Per-shard rows must partition the aggregate, whatever the
        // shard count this iteration drew.
        ensure(
            snap.shards.len() == shards,
            "snapshot must carry one row per shard",
        )?;
        let shard_completed: u64 = snap.shards.iter().map(|s| s.completed).sum();
        let shard_failed: u64 = snap.shards.iter().map(|s| s.failed).sum();
        ensure(
            shard_completed == snap.total_completed() && shard_failed == snap.total_failed(),
            format!(
                "per-shard counters diverge: completed {} vs {}, failed {} vs {}",
                shard_completed,
                snap.total_completed(),
                shard_failed,
                snap.total_failed()
            ),
        )?;
        Ok(())
    });
}

#[test]
fn wire_requests_get_exactly_one_typed_terminal_response() {
    // Fewer cases than the manual-clock property: each iteration spins
    // up a real started service plus a UDS listener. The request mix is
    // what's randomized — ids, tenants, payload values, and a sprinkle
    // of semantically invalid frames that must be answered (BadFrame)
    // without poisoning the connection for later requests.
    check("wire-exactly-one-terminal-response", 12, |rng| {
        let policy = BatchPolicy {
            max_batch: rng.range_usize(1, 4),
            max_delay: Duration::from_micros(rng.range_usize(50, 1000) as u64),
            queue_capacity: rng.range_usize(4, 16),
            ..BatchPolicy::default()
        };
        let breaker = BreakerPolicy {
            failure_threshold: 3,
            cooldown: Duration::from_millis(1),
        };
        let reg = registry(rng, breaker);
        let shards = [1usize, 2][rng.below(2)];
        let svc = Arc::new(InferenceService::start_sharded(
            reg,
            &policy,
            &ShardPolicy::new(shards),
            None,
        ));
        let mut server = WireServer::start(svc, &WireConfig::default());
        let path = temp_uds_path("prop");
        server.listen_uds(&path).map_err(|e| format!("bind UDS: {e}"))?;

        let mut client = WireClient::connect_uds(&path).map_err(|e| format!("connect: {e}"))?;
        client
            .set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(10)))
            .map_err(|e| format!("timeouts: {e}"))?;

        let requests = rng.range_usize(10, 30);
        let mut submitted = 0u64; // well-formed requests the service accepted
        let mut rejected = 0u64; // semantic rejects answered BadFrame
        for id in 0..requests as u64 {
            // Draw the request shape: mostly valid, sometimes broken in
            // one of the ways the server must reject per-request
            // (answer BadFrame, keep the connection open).
            let mut model = MODELS[rng.below(2)].to_string();
            let mut input: Vec<f32> = (0..3).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let expect_reject = match rng.below(10) {
                0 => {
                    // NaN into the f32 plan: rejected at submit. The Q
                    // plan quantizes (saturates), so only "pf" rejects.
                    let poison = model == "pf";
                    input[rng.below(3)] = f32::NAN;
                    poison
                }
                1 => {
                    // Wrong input width.
                    input.push(0.0);
                    true
                }
                2 => {
                    // Unknown model tag.
                    model = "no-such-model".to_string();
                    true
                }
                _ => false,
            };
            let req = RequestFrame { id, tenant: rng.below(4) as u64, model, input };
            // Exactly one terminal response per id, whatever the shape.
            // Sheds are terminal for *that frame* — a retry is a fresh
            // frame reusing the id, which the server permits.
            let mut resp = client.call(&req).map_err(|e| format!("call: {e}"))?;
            let mut attempts = 0;
            while matches!(
                resp.body,
                ResponseBody::Shed { .. } | ResponseBody::Quarantined { .. }
            ) {
                attempts += 1;
                ensure(attempts < 1000, "request shed indefinitely")?;
                std::thread::sleep(Duration::from_micros(200));
                resp = client.call(&req).map_err(|e| format!("call: {e}"))?;
            }
            ensure(resp.id == id, "response id must echo the request id")?;
            match resp.body {
                ResponseBody::BadFrame { .. } => {
                    ensure(expect_reject, "well-formed request answered BadFrame")?;
                    rejected += 1;
                }
                ResponseBody::Ok { .. }
                | ResponseBody::Timeout { .. }
                | ResponseBody::ExecFailed { .. }
                | ResponseBody::Aborted { .. } => {
                    ensure(!expect_reject, "invalid request got a non-reject terminal")?;
                    submitted += 1;
                }
                ResponseBody::Shed { .. } | ResponseBody::Quarantined { .. } => unreachable!(),
            }
        }
        drop(client);

        let (svc, counters) = server.shutdown();
        let Ok(svc) = Arc::try_unwrap(svc) else {
            return Err("service Arc still shared after wire shutdown".to_string());
        };
        let snap = svc.shutdown();
        // Lockstep single client: one response frame per request frame,
        // and the semantic rejects are not wire-level bad frames.
        ensure(counters.frames_rx == counters.frames_tx, "one response per request frame")?;
        ensure(counters.bad_frames == 0, "semantic rejects must not count as bad frames")?;
        ensure(
            counters.connections_opened == 1 && counters.connections_closed == 1,
            "the single connection must open and close exactly once",
        )?;
        ensure(
            snap.total_completed() + snap.total_failed() == submitted,
            format!(
                "service books diverge: completed {} + failed {} != accepted {}",
                snap.total_completed(),
                snap.total_failed(),
                submitted
            ),
        )?;
        ensure(
            submitted + rejected == requests as u64,
            "every request must land in exactly one ledger bucket",
        )?;
        Ok(())
    });
}
