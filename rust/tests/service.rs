//! Multi-tenant inference-service gate.
//!
//! Pins the service-layer contract end to end:
//!
//! * coalesced micro-batch replies are **bit-exact** vs per-request
//!   serial execution, across all three plan families (f32, q32,
//!   packed q7) — micro-batching may change latency, never answers;
//! * the deadline trigger flushes partial batches, deterministically
//!   (manual mode passes an explicit `now`) and in a started service;
//! * backpressure sheds exactly at capacity, leaves no trace, and the
//!   queue recovers after a drain;
//! * per-model and per-tenant counters reconcile with what clients saw;
//! * NaN/inf f32 inputs are rejected synchronously at submit;
//! * the circuit breaker trips after consecutive execution failures,
//!   fast-rejects while quarantined, admits exactly one half-open
//!   probe, and recovers — deterministically, on a virtual clock;
//! * the watchdog respawns a killed dispatcher and requests still
//!   complete (aborted in-flight requests get terminal replies);
//! * dispatcher kills on a sharded service land only on the shard
//!   hosting the faulted model — the other shard never restarts and
//!   never aborts a request;
//! * a tiny `service load` run reports the `BENCH_service.json` schema.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::PackedWidth;
use fann_on_mcu::quantize::quantize;
use fann_on_mcu::service::load::{self, LoadOptions};
use fann_on_mcu::service::{
    BatchPolicy, BreakerPolicy, FaultPlan, HealthState, InferError, InferenceService,
    ModelRegistry, Output, ShardPolicy, SubmitError,
};
use fann_on_mcu::util::rng::Rng;

fn rand_net(sizes: &[usize], seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    n.randomize(&mut rng, None);
    n
}

fn policy(max_batch: usize, max_delay: Duration, capacity: usize) -> BatchPolicy {
    BatchPolicy {
        max_batch,
        max_delay,
        queue_capacity: capacity,
        ..BatchPolicy::default()
    }
}

const HOUR: Duration = Duration::from_secs(3600);

/// The per-request serial reference for one sample, quantizing exactly
/// like `InferenceService::submit` does.
fn serial_reference(reg: &ModelRegistry, model: &str, input: &[f32]) -> Output {
    let m = reg.get(model).unwrap();
    let plan = m.plan();
    if plan.is_float() {
        Output::F32(plan.run_batch_f32(input, 1))
    } else {
        let dec = plan.decimal_point().unwrap();
        let xq: Vec<i32> = input.iter().map(|&v| quantize(v, dec)).collect();
        Output::Q(plan.run_batch_q(&xq, 1))
    }
}

#[test]
fn coalesced_replies_bit_exact_across_plan_families() {
    let f_net = rand_net(&[5, 9, 3], 1);
    let fixed = FixedNetwork::from_float(&rand_net(&[6, 7, 2], 2), 1.0).unwrap();
    let (_, packed) = from_float_packed(&rand_net(&[8, 12, 4], 3), 1.0, PackedWidth::Q7).unwrap();

    let reg = Arc::new(ModelRegistry::new());
    reg.register("f32-model", &f_net).unwrap();
    reg.register("q32-model", &fixed).unwrap();
    reg.register("q7-model", &packed).unwrap();

    // Manual mode + huge deadline: the only flush triggers in play are
    // size (pump) and drain, so batch composition is fully determined.
    let svc = InferenceService::new(Arc::clone(&reg), &policy(4, HOUR, 64));
    let (tx, rx) = mpsc::channel();
    let mut rng = Rng::new(44);
    let mut expected: HashMap<u64, Output> = HashMap::new();
    for (model, n_in) in [("f32-model", 5usize), ("q32-model", 6), ("q7-model", 8)] {
        // 7 requests per model: one size-triggered batch of 4, one
        // drain batch of 3 — both partial-batch and full-batch
        // coalescing get a bit-exactness check.
        for s in 0..7u64 {
            let input: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let want = serial_reference(&reg, model, &input);
            let ticket = svc.submit(model, s, &input, &tx).unwrap();
            assert!(expected.insert(ticket, want).is_none(), "tickets must be unique");
        }
    }

    assert_eq!(svc.pump(), 3, "one size-triggered batch per model");
    assert_eq!(svc.drain(), 3, "one drain batch of 3 per model");

    for _ in 0..expected.len() {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.batch_size == 4 || r.batch_size == 3, "batch_size {}", r.batch_size);
        assert_eq!(
            r.outcome,
            Ok(expected[&r.ticket].clone()),
            "coalesced reply for ticket {} diverged from serial per-request execution",
            r.ticket
        );
    }

    let m = svc.metrics();
    assert_eq!(m.total_completed(), 21);
    for model in ["f32-model", "q32-model", "q7-model"] {
        assert_eq!(m.models[model].size_flushes, 1, "{model}");
        assert_eq!(m.models[model].drain_flushes, 1, "{model}");
        assert_eq!(m.models[model].max_batch_seen, 4, "{model}");
    }
}

#[test]
fn deadline_flush_fires_with_partial_batch() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[3, 5, 2], 9)).unwrap();
    let svc = InferenceService::new(reg, &policy(100, HOUR, 256));
    let (tx, rx) = mpsc::channel();
    for s in 0..3u64 {
        svc.submit("m", s, &[0.1, -0.2, 0.3], &tx).unwrap();
    }
    // Far below both triggers: nothing may flush.
    assert_eq!(svc.pump(), 0);
    // Jump the scheduler clock past the oldest request's deadline: the
    // partial batch (3 of 100) must flush — no sleeping involved.
    assert_eq!(svc.pump_at(Instant::now() + 2 * HOUR), 1);
    for _ in 0..3 {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.batch_size, 3);
    }
    let m = svc.metrics();
    assert_eq!(m.models["m"].deadline_flushes, 1);
    assert_eq!(m.models["m"].size_flushes, 0);
    assert_eq!(m.models["m"].completed, 3);
}

#[test]
fn started_service_flushes_on_deadline() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[4, 6, 2], 10)).unwrap();
    // Size trigger unreachable (1000), so only the 2ms deadline can
    // release these requests.
    let svc = InferenceService::start(reg, &policy(1000, Duration::from_millis(2), 2048));
    let (tx, rx) = mpsc::channel();
    for s in 0..2u64 {
        svc.submit("m", s, &[0.2, 0.4, -0.6, 0.8], &tx).unwrap();
    }
    for _ in 0..2 {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(r.batch_size <= 2);
    }
    let snap = svc.shutdown();
    assert!(
        snap.models["m"].deadline_flushes >= 1,
        "replies arrived without any deadline flush: {:?}",
        snap.models["m"]
    );
    assert_eq!(snap.models["m"].completed, 2);
}

#[test]
fn backpressure_sheds_deterministically_at_capacity_and_recovers() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[2, 4, 2], 11)).unwrap();
    let svc = InferenceService::new(reg, &policy(8, HOUR, 4));
    let (tx, rx) = mpsc::channel();
    for s in 0..4u64 {
        svc.submit("m", s, &[0.1, 0.2], &tx).unwrap();
    }
    // The 5th and 6th arrivals are shed — synchronously, no ticket, no
    // queue mutation.
    for s in 4..6u64 {
        assert_eq!(
            svc.submit("m", s, &[0.1, 0.2], &tx),
            Err(SubmitError::QueueFull { capacity: 4 })
        );
    }
    let m = svc.metrics();
    assert_eq!(m.models["m"].requests, 4);
    assert_eq!(m.models["m"].shed, 2);
    assert_eq!(m.tenants[&4].shed, 1);
    assert_eq!(m.tenants[&5].shed, 1);

    // Draining frees capacity; the queue accepts again.
    assert_eq!(svc.drain(), 1);
    assert_eq!(rx.try_iter().count(), 4);
    svc.submit("m", 6, &[0.1, 0.2], &tx).unwrap();
    assert_eq!(svc.metrics().models["m"].shed, 2, "recovered submits shed nothing");
}

#[test]
fn submit_rejects_unknown_model_and_bad_width() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[3, 4, 2], 12)).unwrap();
    let svc = InferenceService::new(reg, &BatchPolicy::default());
    let (tx, _rx) = mpsc::channel();
    assert_eq!(
        svc.submit("ghost", 0, &[0.0; 3], &tx),
        Err(SubmitError::UnknownModel("ghost".to_string()))
    );
    assert_eq!(
        svc.submit("m", 0, &[0.0; 4], &tx),
        Err(SubmitError::BadInputWidth { expected: 3, got: 4 })
    );
    // NaN/inf on the f32 path is rejected synchronously: one poisoned
    // sample would otherwise corrupt every request coalesced into the
    // same kernel call.
    assert_eq!(
        svc.submit("m", 0, &[f32::NAN, 0.0, 0.0], &tx),
        Err(SubmitError::BadInput { index: 0 })
    );
    assert_eq!(
        svc.submit("m", 0, &[0.0, 0.0, f32::NEG_INFINITY], &tx),
        Err(SubmitError::BadInput { index: 2 })
    );
    assert_eq!(svc.metrics().total_requests(), 0);
}

#[test]
fn quarantine_trips_probes_and_recovers_end_to_end() {
    let reg = Arc::new(ModelRegistry::with_breaker(BreakerPolicy {
        failure_threshold: 2,
        cooldown: Duration::from_millis(50),
    }));
    reg.register("m", &rand_net(&[2, 3, 1], 21)).unwrap();
    // Execution attempts 0 and 1 panic; everything later succeeds.
    let faults = FaultPlan {
        panic_model: "m".to_string(),
        panic_from: 0,
        panic_until: 2,
        ..FaultPlan::default()
    };
    let svc =
        InferenceService::new_with_faults(Arc::clone(&reg), &policy(1, HOUR, 64), Some(faults));
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    // Two failing executions (max_batch 1: one request per batch) trip
    // the breaker at the threshold.
    svc.submit_at("m", 1, &[0.1, 0.2], &tx, t0).unwrap();
    svc.submit_at("m", 2, &[0.1, 0.2], &tx, t0).unwrap();
    assert_eq!(svc.pump_at(t0), 2);
    for _ in 0..2 {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(matches!(r.outcome, Err(InferError::ExecFailed { .. })), "{:?}", r.outcome);
    }
    assert_eq!(reg.health("m"), HealthState::Open);
    // During the cooldown, submits fast-reject without touching the
    // queue.
    assert_eq!(
        svc.submit_at("m", 3, &[0.1, 0.2], &tx, t0 + Duration::from_millis(10)),
        Err(SubmitError::Quarantined { model: "m".to_string() })
    );
    // Once the cooldown elapses exactly one probe is admitted...
    let t1 = t0 + Duration::from_millis(50);
    svc.submit_at("m", 4, &[0.1, 0.2], &tx, t1).unwrap();
    assert_eq!(reg.health("m"), HealthState::HalfOpen);
    // ...and concurrent submits keep rejecting while it is in flight.
    assert!(matches!(
        svc.submit_at("m", 5, &[0.1, 0.2], &tx, t1),
        Err(SubmitError::Quarantined { .. })
    ));
    // The probe executes (attempt #2, past the panic window): recovery.
    assert_eq!(svc.pump_at(t1), 1);
    assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    assert_eq!(reg.health("m"), HealthState::Closed);
    // Healthy again: normal admission, normal execution.
    svc.submit_at("m", 6, &[0.1, 0.2], &tx, t1).unwrap();
    assert_eq!(svc.pump_at(t1), 1);
    assert!(rx.recv_timeout(Duration::from_secs(5)).unwrap().is_ok());
    let m = svc.metrics();
    let mm = &m.models["m"];
    assert_eq!(mm.exec_failures, 2);
    assert_eq!(mm.quarantine_trips, 1);
    assert_eq!(mm.quarantine_probes, 1);
    assert_eq!(mm.quarantine_recoveries, 1);
    assert_eq!(mm.rejected_quarantined, 2);
    assert_eq!(mm.completed, 2);
    assert_eq!(mm.failed, 2);
}

#[test]
fn watchdog_respawns_dispatcher_after_injected_kills() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[2, 3, 1], 22)).unwrap();
    // The dispatcher is killed at its first two loop iterations; the
    // watchdog must fail whatever was pending (terminal Aborted
    // replies, never silence) and respawn it both times.
    let faults = FaultPlan {
        kill_at_iters: vec![0, 1],
        ..FaultPlan::default()
    };
    let svc = InferenceService::start_with_faults(
        reg,
        &policy(4, Duration::from_millis(1), 64),
        Some(faults),
    );
    let (tx, rx) = mpsc::channel();
    let mut completed = false;
    for _ in 0..100 {
        svc.submit("m", 1, &[0.5, -0.5], &tx).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        match r.outcome {
            Ok(_) => {
                completed = true;
                break;
            }
            // Submitted into a dispatcher-death window: terminal reply
            // received, resubmit.
            Err(InferError::Aborted { .. }) => continue,
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }
    assert!(completed, "no request completed after the watchdog respawns");
    let snap = svc.shutdown();
    assert_eq!(snap.watchdog_restarts, 2);
    assert!(snap.dispatcher_heartbeats >= 2);
    // Exactly one terminal reply per accepted request, even across
    // restarts.
    assert_eq!(
        snap.total_completed() + snap.total_failed(),
        snap.total_requests(),
        "{snap:?}"
    );
}

#[test]
fn sharded_started_service_isolates_kills_to_one_shard() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("bad", &rand_net(&[2, 3, 1], 31)).unwrap();
    reg.register("good", &rand_net(&[2, 3, 1], 32)).unwrap();
    reg.pin_shard("bad", 0);
    reg.pin_shard("good", 1);
    // Kills are injected at the first two loop iterations of the shard
    // hosting the faulted model — shard 1 must never see one.
    let faults = FaultPlan {
        panic_model: "bad".to_string(),
        kill_at_iters: vec![0, 1],
        ..FaultPlan::default()
    };
    let svc = InferenceService::start_sharded(
        Arc::clone(&reg),
        &policy(4, Duration::from_millis(1), 64),
        &ShardPolicy::new(2),
        Some(faults),
    );
    assert_eq!(svc.shard_count(), 2);
    assert_eq!(svc.shard_of("bad"), 0);
    assert_eq!(svc.shard_of("good"), 1);
    let (tx, rx) = mpsc::channel();
    // Every request on the healthy shard completes — no Aborted replies
    // leak across the shard boundary even while shard 0 is dying.
    for s in 0..8u64 {
        svc.submit("good", s, &[0.5, -0.5], &tx).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(r.is_ok(), "healthy-shard request aborted: {:?}", r.outcome);
    }
    // The killed shard recovers via its own watchdog, exactly like the
    // single-shard test above.
    let mut completed = false;
    for _ in 0..100 {
        svc.submit("bad", 1, &[0.5, -0.5], &tx).unwrap();
        let r = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        match r.outcome {
            Ok(_) => {
                completed = true;
                break;
            }
            Err(InferError::Aborted { .. }) => continue,
            Err(e) => panic!("unexpected terminal error: {e}"),
        }
    }
    assert!(completed, "the killed shard never recovered");
    let snap = svc.shutdown();
    assert_eq!(snap.shards.len(), 2);
    assert_eq!(snap.watchdog_restarts, 2);
    assert_eq!(snap.shards[0].restarts, 2, "kills land on the faulted model's shard");
    assert_eq!(snap.shards[1].restarts, 0, "the healthy shard never restarts");
    assert_eq!(snap.shards[1].failed, 0, "no aborted replies on the healthy shard");
    assert_eq!(
        snap.shards[0].completed + snap.shards[1].completed,
        snap.total_completed(),
        "per-shard completed rows partition the aggregate"
    );
}

#[test]
fn per_tenant_and_per_model_counters_reconcile() {
    let reg = Arc::new(ModelRegistry::new());
    reg.register("m", &rand_net(&[2, 3, 2], 13)).unwrap();
    let svc = InferenceService::new(reg, &policy(4, HOUR, 64));
    let (tx, rx) = mpsc::channel();
    for tenant in [1u64, 1, 2, 2] {
        svc.submit("m", tenant, &[0.3, -0.3], &tx).unwrap();
    }
    assert_eq!(svc.pump(), 1);
    assert_eq!(rx.try_iter().count(), 4);
    let m = svc.metrics();
    assert_eq!(m.tenants[&1].requests, 2);
    assert_eq!(m.tenants[&1].completed, 2);
    assert_eq!(m.tenants[&2].completed, 2);
    let mm = &m.models["m"];
    assert_eq!(mm.batches, 1);
    assert!((mm.mean_batch() - 4.0).abs() < 1e-9);
    // Every completed request shared its batch: fully coalesced.
    assert!((mm.batched_ratio() - 1.0).abs() < 1e-9);
    assert!(mm.latency.count() == 4 && mm.latency.p99() >= mm.latency.p50());
}

#[test]
fn load_harness_smoke_reports_the_bench_schema() {
    let opts = LoadOptions {
        clients: 30,
        requests_per_client: 2,
        seed: 5,
        submitters: 3,
        shards: 2,
        wire: false,
        policy: policy(8, Duration::from_micros(500), 128),
    };
    let report = load::run(&opts).unwrap();
    assert_eq!(report.total_requests, 60);
    assert!(report.bit_exact);
    assert!(report.samples_per_sec > 0.0 && report.serial_samples_per_sec > 0.0);
    assert!(report.p99_us >= report.p50_us);
    assert_eq!(report.rows.len(), 3, "emg-q7 + ecg-q32 + eeg-f32");
    assert_eq!(report.rows.iter().map(|r| r.completed).sum::<u64>(), 60);
    assert_eq!(report.tenants, 30);
    assert_eq!(report.shard_rows.len(), 2, "one counter row per dispatcher shard");
    assert_eq!(report.shard_rows.iter().map(|s| s.completed).sum::<u64>(), 60);
    let json = report.to_json().to_pretty();
    for field in [
        "\"schema\": \"fann-on-mcu/bench-service/v1\"",
        "\"samples_per_sec\"",
        "\"p50_us\"",
        "\"p99_us\"",
        "\"ratchet_mean_batch\"",
        "\"speedup_service_vs_serial\"",
        "\"bit_exact\": true",
        "\"emg-q7\"",
        "\"ecg-q32\"",
        "\"eeg-f32\"",
        "\"shards\"",
        "\"shards_detail\"",
        "\"head_of_line\"",
        "\"cold_p99_us_sharded\"",
    ] {
        assert!(json.contains(field), "missing {field}");
    }
}
