//! End-to-end application showcase tests (Sec. VI): train each app,
//! verify accuracy bands, deploy to all Table II targets, check the
//! paper's runtime/energy ordering.

use fann_on_mcu::apps::{self, ACTIVITY, FALL, GESTURE};
use fann_on_mcu::targets::{Chip, Target};

#[test]
fn fall_detection_full_showcase() {
    let app = apps::train_app(&FALL, 21).unwrap();
    assert!(
        (0.72..=1.0).contains(&app.test_accuracy),
        "app B accuracy {} (paper 84%)",
        app.test_accuracy
    );
    let x = vec![0.1f32; 117];
    let mut times = Vec::new();
    for t in Target::table2_targets() {
        let (_, r) = apps::run_on_target(&app, t, &x).unwrap();
        times.push((t.label(), r.seconds, r.energy_uj));
    }
    // Paper ordering: M4 slowest, multi-RI5CY fastest.
    assert!(times[0].1 > times[1].1, "M4 should be slower than IBEX");
    assert!(times[2].1 > times[3].1, "single > multi RI5CY");
    // Sub-millisecond on all Wolf configurations (paper: 0.3/0.14/0.03 ms).
    for (label, secs, _) in &times[1..] {
        assert!(*secs < 1.0e-3, "{label}: {secs}");
    }
}

#[test]
fn activity_showcase_microsecond_range() {
    let app = apps::train_app(&ACTIVITY, 22).unwrap();
    let x = vec![0.1f32; 7];
    let (_, r) = apps::run_on_target(&app, Target::WolfCluster { cores: 8 }, &x).unwrap();
    // Paper: 0.004 ms (4 µs) compute for app C on 8 cores.
    assert!(
        r.seconds < 30.0e-6,
        "app C multi-core compute {} s",
        r.seconds
    );
    // vs the FPGA of [46]: 270 ns at 241 mW. The paper's point is energy:
    // even the slower MCU beats the FPGA's energy by orders of magnitude.
    let fpga_energy_uj = 270e-9 * 241.0 * 1e3;
    let (_, r_fc) = apps::run_on_target(&app, Target::WolfFc, &x).unwrap();
    assert!(r_fc.energy_uj < fpga_energy_uj * 0.1 * 1e3);
}

#[test]
fn gesture_runtime_ordering_matches_table2() {
    let app = apps::train_app(&GESTURE, 23).unwrap();
    assert!(
        app.test_accuracy > 0.70,
        "app A accuracy {} (paper 85.58%)",
        app.test_accuracy
    );
    let x = vec![0.1f32; 76];

    let (_, m4) = apps::run_on_target(&app, Target::CortexM4(Chip::Nrf52832), &x).unwrap();
    let (_, ibex) = apps::run_on_target(&app, Target::WolfFc, &x).unwrap();
    let (_, single) = apps::run_on_target(&app, Target::WolfCluster { cores: 1 }, &x).unwrap();
    let (_, multi) = apps::run_on_target(&app, Target::WolfCluster { cores: 8 }, &x).unwrap();

    // Table II shape: 17.6 / 11.4 / 5.7 / 0.8 ms.
    assert!((10e-3..25e-3).contains(&m4.seconds), "M4 {}", m4.seconds);
    assert!((8e-3..15e-3).contains(&ibex.seconds), "IBEX {}", ibex.seconds);
    assert!(
        (4e-3..8e-3).contains(&single.seconds),
        "1xRI5CY {}",
        single.seconds
    );
    assert!(
        (0.5e-3..1.2e-3).contains(&multi.seconds),
        "8xRI5CY {}",
        multi.seconds
    );

    // Energy: paper 183.7 / 122.6 / 116.0 / 49.4 µJ (compute phase).
    assert!(multi.energy_uj < single.energy_uj);
    assert!(single.energy_uj < ibex.energy_uj);
    assert!(ibex.energy_uj < m4.energy_uj);

    // Headline: 22x speedup, −73% energy for continuous classification.
    let speedup = m4.seconds / multi.seconds;
    assert!((17.0..27.0).contains(&speedup), "headline speedup {speedup}");
}

#[test]
fn fixed_and_float_agree_on_deployed_decisions() {
    let app = apps::train_app(&FALL, 24).unwrap();
    let data = FALL.dataset(24);
    let mut agree = 0;
    let n = 50;
    for i in 0..n {
        let x = data.input(i);
        let f = fann_on_mcu::util::argmax(&app.net.run(x));
        let (_, r) = apps::run_on_target(&app, Target::WolfFc, x).unwrap();
        let q = fann_on_mcu::util::argmax(&r.outputs);
        if f == q {
            agree += 1;
        }
    }
    assert!(agree >= 45, "{agree}/{n} agreement between float and fixed");
}
