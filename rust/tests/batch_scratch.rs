//! Zero-allocation regression for the batch execution path: repeated
//! same-shape batches through [`Network::run_batch_into`] /
//! [`FixedNetwork::run_batch_q_into`] must never reallocate the
//! [`BatchScratch`] arena (capacity and base pointers stay put), and
//! the parallel driver's persistent pool must keep outputs bit-stable
//! across repeated streams.

use fann_on_mcu::bench::batch::{run_batch_parallel, BatchPool};
use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::{self, BatchScratch, PackedWidth};
use fann_on_mcu::util::rng::Rng;

fn random_net(sizes: &[usize], seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let mut net = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    net
}

#[test]
fn float_scratch_never_reallocates_on_same_shape_calls() {
    let net = random_net(&[10, 32, 16, 4], 7);
    let mut rng = Rng::new(3);
    let n = 33;
    let xs: Vec<f32> = (0..n * 10).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut scratch = BatchScratch::new();
    let mut out = vec![0.0f32; n * 4];
    let kernel = kernels::default_f32();

    // First call grows the arena once.
    net.run_batch_into(kernel, &xs, n, &mut scratch, &mut out);
    let cap = scratch.capacity();
    let ptrs = scratch.base_ptrs();
    let want = out.clone();

    for _ in 0..50 {
        net.run_batch_into(kernel, &xs, n, &mut scratch, &mut out);
    }
    assert_eq!(scratch.capacity(), cap, "scratch capacity changed");
    assert_eq!(scratch.base_ptrs(), ptrs, "scratch storage moved");
    assert_eq!(out, want, "outputs drifted across reuse");

    // Smaller batches through the same arena: still no reallocation.
    let mut small_out = vec![0.0f32; 5 * 4];
    net.run_batch_into(kernel, &xs[..5 * 10], 5, &mut scratch, &mut small_out);
    assert_eq!(scratch.capacity(), cap);
    assert_eq!(scratch.base_ptrs(), ptrs);
    assert_eq!(&small_out[..], &want[..5 * 4], "prefix batch diverged");
}

#[test]
fn fixed_and_packed_scratch_never_reallocate() {
    let net = random_net(&[8, 24, 6], 11);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let (_, packed) = from_float_packed(&net, 1.0, PackedWidth::Q7).unwrap();
    let mut rng = Rng::new(5);
    let n = 21;
    let xs: Vec<f32> = (0..n * 8).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let q = fixed.quantize_input(&xs);
    let q7 = packed.quantize_input(&xs);

    let mut scratch: BatchScratch<i32> = BatchScratch::new();
    let mut out = vec![0i32; n * 6];
    fixed.run_batch_q_into(&q, n, &mut scratch, &mut out);
    let cap = scratch.capacity();
    let ptrs = scratch.base_ptrs();
    let want = out.clone();
    for _ in 0..30 {
        fixed.run_batch_q_into(&q, n, &mut scratch, &mut out);
        // The packed net shares the same arena (same element type and
        // width bound): still no growth.
        packed.run_batch_q_into(&q7, n, &mut scratch, &mut out);
    }
    assert_eq!(scratch.capacity(), cap);
    assert_eq!(scratch.base_ptrs(), ptrs);
    fixed.run_batch_q_into(&q, n, &mut scratch, &mut out);
    assert_eq!(out, want);
}

#[test]
fn vec_api_matches_into_api_bitwise() {
    let net = random_net(&[9, 14, 5], 23);
    let mut rng = Rng::new(9);
    let n = 12;
    let xs: Vec<f32> = (0..n * 9).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    for kernel in kernels::f32_kernels() {
        let want = net.run_batch_with_kernel(kernel, &xs, n);
        let mut scratch = BatchScratch::new();
        let mut got = vec![0.0f32; n * 5];
        net.run_batch_into(kernel, &xs, n, &mut scratch, &mut got);
        assert_eq!(got, want, "{}", kernel.name());
    }
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let q = fixed.quantize_input(&xs);
    let want = fixed.run_batch_q(&q, n);
    let mut scratch = BatchScratch::new();
    let mut got = vec![0i32; n * 5];
    fixed.run_batch_q_into(&q, n, &mut scratch, &mut got);
    assert_eq!(got, want);
}

#[test]
fn growth_happens_once_then_larger_shapes_reuse() {
    let net = random_net(&[6, 20, 3], 41);
    let kernel = kernels::default_f32();
    let mut scratch = BatchScratch::new();
    let mut rng = Rng::new(2);
    // Grow to the largest batch first …
    let big = 64;
    let xs_big: Vec<f32> = (0..big * 6).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let mut out_big = vec![0.0f32; big * 3];
    net.run_batch_into(kernel, &xs_big, big, &mut scratch, &mut out_big);
    let cap = scratch.capacity();
    let ptrs = scratch.base_ptrs();
    // … then every smaller batch reuses the arena untouched.
    for n in [1usize, 7, 16, 63] {
        let xs: Vec<f32> = xs_big[..n * 6].to_vec();
        let mut out = vec![0.0f32; n * 3];
        net.run_batch_into(kernel, &xs, n, &mut scratch, &mut out);
        assert_eq!(scratch.capacity(), cap, "n={n}");
        assert_eq!(scratch.base_ptrs(), ptrs, "n={n}");
        assert_eq!(&out[..], &out_big[..n * 3], "n={n}");
    }
}

#[test]
fn thread_scratch_steady_state_for_vec_api() {
    // The convenience Vec-returning API routes through the thread-local
    // arena: after the first call it must stop growing too.
    let net = random_net(&[7, 18, 4], 53);
    let mut rng = Rng::new(8);
    let n = 17;
    let xs: Vec<f32> = (0..n * 7).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let _ = net.run_batch(&xs, n); // warm the TLS arena
    let cap = kernels::with_thread_scratch_f32(|s| s.capacity());
    let want = net.run_batch(&xs, n);
    for _ in 0..20 {
        assert_eq!(net.run_batch(&xs, n), want);
    }
    assert_eq!(kernels::with_thread_scratch_f32(|s| s.capacity()), cap);
}

#[test]
fn parallel_driver_stable_across_repeated_streams() {
    // The persistent pool serves many batches; outputs stay bit-equal
    // to serial every time (workers' TLS arenas are reused, never
    // corrupted by earlier batches of different shape).
    let net_a = random_net(&[5, 11, 4], 61);
    let net_b = random_net(&[12, 7, 2], 67);
    let mut rng = Rng::new(13);
    for round in 0..5 {
        for (net, n_in, n) in [(&net_a, 5usize, 19usize), (&net_b, 12, 8)] {
            let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let serial = net.run_batch(&xs, n);
            for threads in [2usize, 4] {
                assert_eq!(
                    run_batch_parallel(net, &xs, n, threads),
                    serial,
                    "round={round} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn local_pool_shutdown_is_clean() {
    // A scoped pool joins its workers on drop; dropping right after
    // executing borrowed jobs must be safe and leak-free.
    let data = vec![1u64, 2, 3, 4];
    let sum = std::sync::Mutex::new(0u64);
    {
        let pool = BatchPool::new(2);
        assert_eq!(pool.workers(), 2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .iter()
            .map(|&v| {
                Box::new(move || {
                    *sum.lock().unwrap() += v;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.execute(jobs);
    } // drop joins the workers
    assert_eq!(*sum.lock().unwrap(), 10);
}
