//! The seed's previously-untested training path, end to end: iRPROP−
//! and batch backprop convergence on deterministic seeds (XOR + a
//! 2-class blob set from `datasets::`), then the full
//! trained → quantized → emitted → emulated pipeline, which must
//! classify the training set identically to the host path.

use fann_on_mcu::codegen::{emit_fixed, emit_float, NetRepr};
use fann_on_mcu::datasets::{self, SyntheticSpec};
use fann_on_mcu::emulator::{emulate, emulate_q};
use fann_on_mcu::fann::train::backprop::{BackpropConfig, Batch};
use fann_on_mcu::fann::train::rprop::{Rprop, RpropConfig};
use fann_on_mcu::fann::train::{accuracy, mse};
use fann_on_mcu::fann::{Activation, FixedNetwork, Network, TrainData};
use fann_on_mcu::targets::Target;
use fann_on_mcu::util::argmax;
use fann_on_mcu::util::rng::Rng;

fn xor_data() -> TrainData {
    datasets::xor()
}

/// Well-separated 2-class blobs: wide enough margins that quantization
/// cannot flip a decision, small enough to train in milliseconds. The
/// generator draws class-mean *directions* at random, so the first seed
/// whose empirical class means are far apart is picked deterministically
/// (the scan itself is fixed, so the test is fully reproducible).
fn blob_data() -> TrainData {
    for seed in 11..32 {
        let data = datasets::generate(
            SyntheticSpec {
                num_features: 4,
                num_classes: 2,
                samples_per_class: 50,
                separation: 4.0,
                spread: 0.5,
                seed,
            },
            true,
        );
        if class_mean_distance(&data) > 3.0 {
            return data;
        }
    }
    panic!("no seed in 11..32 produced separable blobs");
}

fn class_mean_distance(data: &TrainData) -> f32 {
    let k = data.num_inputs;
    let mut means = [vec![0.0f32; k], vec![0.0f32; k]];
    let mut counts = [0usize; 2];
    for i in 0..data.len() {
        let c = data.label(i);
        counts[c] += 1;
        for (m, v) in means[c].iter_mut().zip(data.input(i)) {
            *m += v;
        }
    }
    for (m, &cnt) in means.iter_mut().zip(&counts) {
        m.iter_mut().for_each(|v| *v /= cnt.max(1) as f32);
    }
    means[0]
        .iter()
        .zip(&means[1])
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        .sqrt()
}

#[test]
fn rprop_converges_on_xor_with_deterministic_seed() {
    let mut rng = Rng::new(42);
    let mut net = Network::new(&[2, 4, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let data = xor_data();
    let mut tr = Rprop::new(&net, RpropConfig::default());
    let curve = tr.train_until(&mut net, &data, 500, 0.001);
    assert!(
        *curve.last().unwrap() <= 0.001,
        "rprop failed to converge on XOR: tail {:?}",
        &curve[curve.len().saturating_sub(3)..]
    );
    for (x, want) in [
        ([0.0f32, 0.0], false),
        ([0.0, 1.0], true),
        ([1.0, 0.0], true),
        ([1.0, 1.0], false),
    ] {
        assert_eq!(net.run(&x)[0] >= 0.5, want, "XOR({x:?})");
    }
}

#[test]
fn batch_backprop_still_learns_after_refactor() {
    let mut rng = Rng::new(7);
    let mut net = Network::new(&[2, 6, 1], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let data = xor_data();
    let before = mse(&net, &data);
    let mut tr = Batch::new(
        &net,
        BackpropConfig {
            learning_rate: 0.05,
            momentum: 0.0,
        },
    );
    for _ in 0..400 {
        tr.train_epoch(&mut net, &data);
    }
    let after = mse(&net, &data);
    assert!(
        after < before * 0.95,
        "batch backprop made no progress: {before} -> {after}"
    );
}

#[test]
fn rprop_learns_blobs_and_quantized_emulated_pipeline_classifies_identically() {
    let data = blob_data();
    let mut rng = Rng::new(99);
    let mut net = Network::new(&[4, 8, 2], Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    let mut tr = Rprop::new(&net, RpropConfig::default());
    tr.train_until(&mut net, &data, 200, 0.005);
    let acc = accuracy(&net, &data);
    assert!(acc >= 0.98, "trained accuracy only {acc}");

    // Quantize, emit for the FC, emulate — decisions must match the
    // host float path on every training sample, and the emulated Q
    // outputs must be bit-exact vs the host fixed path.
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let bundle = emit_fixed(&fixed, Target::WolfFc).unwrap();
    for i in 0..data.len() {
        let x = data.input(i);
        let host_float = argmax(&net.run(x));
        let xq = fixed.quantize_input(x);
        let host_q = fixed.run_q(&xq);
        let rep = emulate_q(&bundle.artifact, &xq).unwrap();
        assert_eq!(
            rep.outputs_q.as_deref().unwrap(),
            &host_q[..],
            "sample {i}: emulated Q outputs diverged from host fixed path"
        );
        assert_eq!(
            argmax(&rep.outputs),
            host_float,
            "sample {i}: emulated decision diverged from host float decision"
        );
    }

    // The same contract holds for the float artifact on an FPU target.
    let bundle_f = emit_float(&net, Target::WolfCluster { cores: 8 }, NetRepr::F32, 1.0).unwrap();
    for i in 0..data.len() {
        let x = data.input(i);
        let rep = emulate(&bundle_f.artifact, x).unwrap();
        assert_eq!(rep.outputs, net.run(x), "sample {i}");
    }
}
