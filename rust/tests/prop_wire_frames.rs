//! Round-trip + corruption properties for the wire-protocol codec
//! (`service::frame`): encoding any request/response and decoding it
//! back is bitwise identity — ids, tags, dtypes, and payloads of every
//! size including empty and exactly-at-the-cap — while corrupted bytes
//! (truncation at every offset, flipped magic/version/kind/dtype
//! bytes, oversized length prefixes, dtype/payload-length mismatches,
//! non-UTF-8 text) produce typed [`FrameError`]s, never a panic and
//! never a read past the buffer.

use fann_on_mcu::service::frame::{
    self, FrameError, RequestFrame, ResponseBody, ResponseFrame, WireDtype, DEFAULT_MAX_FRAME,
    LEN_PREFIX, MAX_TAG, REQUEST_HEADER, RESPONSE_HEADER, VERSION,
};
use fann_on_mcu::service::Output;
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

const TAG_ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789-_";

fn random_tag(rng: &mut Rng) -> String {
    let len = rng.range_usize(1, MAX_TAG);
    (0..len).map(|_| TAG_ALPHABET[rng.below(TAG_ALPHABET.len())] as char).collect()
}

fn random_text(rng: &mut Rng) -> String {
    let len = rng.below(41);
    (0..len).map(|_| TAG_ALPHABET[rng.below(TAG_ALPHABET.len())] as char).collect()
}

/// A request with arbitrary f32 *bit patterns* — NaNs, infinities and
/// denormals included — plus payload sizes from empty upward.
fn random_request(rng: &mut Rng) -> RequestFrame {
    let n = match rng.below(4) {
        0 => 0,
        1 => rng.range_usize(1, 4),
        _ => rng.range_usize(1, 256),
    };
    RequestFrame {
        id: rng.next_u64(),
        tenant: rng.next_u64(),
        model: random_tag(rng),
        input: (0..n).map(|_| f32::from_bits(rng.next_u64() as u32)).collect(),
    }
}

fn random_response(rng: &mut Rng) -> ResponseFrame {
    let id = rng.next_u64();
    let n = rng.below(9);
    let body = match rng.below(7) {
        0 => ResponseBody::Ok {
            output: if rng.below(2) == 0 {
                Output::F32((0..n).map(|_| rng.range_f32(-8.0, 8.0)).collect())
            } else {
                Output::Q((0..n).map(|_| rng.next_u64() as i32).collect())
            },
            latency_us: rng.next_u64() >> 20,
            batch: rng.range_usize(1, 64) as u64,
        },
        1 => ResponseBody::Shed { detail: random_text(rng) },
        2 => ResponseBody::Quarantined { detail: random_text(rng) },
        3 => ResponseBody::Timeout {
            waited_us: rng.next_u64() >> 30,
            budget_us: rng.next_u64() >> 30,
        },
        4 => ResponseBody::ExecFailed { detail: random_text(rng) },
        5 => ResponseBody::Aborted { detail: random_text(rng) },
        _ => ResponseBody::BadFrame { detail: random_text(rng) },
    };
    ResponseFrame { id, body }
}

fn encode_req(req: &RequestFrame) -> Vec<u8> {
    let mut buf = Vec::new();
    frame::encode_request(req, &mut buf);
    buf
}

fn encode_resp(resp: &ResponseFrame) -> Vec<u8> {
    let mut buf = Vec::new();
    frame::encode_response(resp, &mut buf);
    buf
}

#[test]
fn request_roundtrip_is_bitwise_identity() {
    check("request round-trip", 200, |rng| {
        let req = random_request(rng);
        let buf = encode_req(&req);
        let (body, consumed) =
            frame::split_frame(&buf, DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        ensure(consumed == buf.len(), "split did not consume the whole frame")?;
        let back = frame::decode_request(body).map_err(|e| e.to_string())?;
        ensure(back.id == req.id, "id changed")?;
        ensure(back.tenant == req.tenant, "tenant changed")?;
        ensure(back.model == req.model, "model tag changed")?;
        // Bit-level payload equality: NaN payloads are representable
        // on the wire by design (rejection is the service's job), so
        // `==` on f32 would be wrong here.
        let bits: Vec<u32> = req.input.iter().map(|v| v.to_bits()).collect();
        let back_bits: Vec<u32> = back.input.iter().map(|v| v.to_bits()).collect();
        ensure(bits == back_bits, "payload bits changed")
    });
}

#[test]
fn response_roundtrip_preserves_every_kind() {
    check("response round-trip", 200, |rng| {
        let resp = random_response(rng);
        let buf = encode_resp(&resp);
        let (body, consumed) =
            frame::split_frame(&buf, DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        ensure(consumed == buf.len(), "split did not consume the whole frame")?;
        let back = frame::decode_response(body).map_err(|e| e.to_string())?;
        ensure(
            back == resp,
            format!("response changed: {resp:?} -> {back:?}"),
        )
    });
}

#[test]
fn frames_stream_back_to_back() {
    check("frame streaming", 60, |rng| {
        let a = random_request(rng);
        let b = random_request(rng);
        let mut buf = encode_req(&a);
        frame::encode_request(&b, &mut buf);
        let (body_a, used_a) =
            frame::split_frame(&buf, DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        let back_a = frame::decode_request(body_a).map_err(|e| e.to_string())?;
        ensure(back_a.id == a.id && back_a.model == a.model, "first frame mangled")?;
        let (body_b, used_b) =
            frame::split_frame(&buf[used_a..], DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        ensure(used_a + used_b == buf.len(), "streamed split lost bytes")?;
        let back_b = frame::decode_request(body_b).map_err(|e| e.to_string())?;
        ensure(back_b.id == b.id && back_b.model == b.model, "second frame mangled")
    });
}

#[test]
fn truncation_at_every_byte_offset_never_panics() {
    check("truncation fuzz", 80, |rng| {
        let buf = if rng.below(2) == 0 {
            encode_req(&random_request(rng))
        } else {
            encode_resp(&random_response(rng))
        };
        // The stream view: every proper prefix of the full frame must
        // report Truncated (the length prefix declares the full body).
        for cut in 0..buf.len() {
            match frame::split_frame(&buf[..cut], DEFAULT_MAX_FRAME) {
                Err(FrameError::Truncated { needed, got }) => {
                    ensure(got == cut && needed > cut, "wrong Truncated accounting")?;
                }
                other => return Err(format!("cut {cut}: expected Truncated, got {other:?}")),
            }
        }
        // The body view: a decoder handed any prefix of the body must
        // return — a typed error or a shorter-but-well-formed parse
        // (the length prefix, not the decoder, is the framing
        // authority) — and never panic or over-read.
        let body = &buf[LEN_PREFIX..];
        for cut in 0..body.len() {
            let _ = frame::decode_request(&body[..cut]);
            let _ = frame::decode_response(&body[..cut]);
        }
        Ok(())
    });
}

#[test]
fn corrupt_request_headers_yield_typed_errors() {
    check("request header corruption", 120, |rng| {
        let req = random_request(rng);
        let buf = encode_req(&req);
        let body = buf[LEN_PREFIX..].to_vec();

        // Flipped magic byte.
        let mut bad = body.clone();
        let i = rng.below(4);
        bad[i] ^= 1 + rng.below(255) as u8;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadMagic { .. })),
            "flipped magic not rejected",
        )?;

        // Wrong version.
        let mut bad = body.clone();
        bad[4] = VERSION.wrapping_add(1 + rng.below(254) as u8);
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadVersion { .. })),
            "flipped version not rejected",
        )?;

        // A response kind byte (or garbage) in a request.
        let mut bad = body.clone();
        bad[5] = 1 + rng.below(255) as u8;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadKind { .. })),
            "bad kind not rejected",
        )?;

        // Unknown dtype code.
        let mut bad = body.clone();
        bad[6] = 2 + rng.below(254) as u8;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadDtype { .. })),
            "bad dtype not rejected",
        )?;

        // Tag length 0 and > MAX_TAG are both out of band.
        let mut bad = body.clone();
        bad[7] = 0;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadTag { len: 0 })),
            "zero tag not rejected",
        )?;
        let mut bad = body.clone();
        bad[7] = (MAX_TAG + 1 + rng.below(255 - MAX_TAG)) as u8;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadTag { .. })),
            "oversized tag not rejected",
        )?;

        // 0xFF is not valid anywhere in UTF-8: poison one tag byte.
        let mut bad = body.clone();
        bad[REQUEST_HEADER + rng.below(req.model.len())] = 0xFF;
        ensure(
            matches!(frame::decode_request(&bad), Err(FrameError::BadText)),
            "non-UTF-8 tag not rejected",
        )
    });
}

#[test]
fn dtype_payload_length_mismatch_is_typed() {
    check("payload mismatch", 120, |rng| {
        let mut req = random_request(rng);
        if req.input.is_empty() {
            req.input.push(1.0);
        }
        let buf = encode_req(&req);
        let body = &buf[LEN_PREFIX..];
        // Lop 1–3 bytes off the payload: no longer whole f32 elements.
        let chop = rng.range_usize(1, 3);
        match frame::decode_request(&body[..body.len() - chop]) {
            Err(FrameError::PayloadMismatch { dtype: WireDtype::F32, bytes }) => {
                ensure(bytes % 4 != 0, "mismatch reported for whole elements")?;
            }
            other => return Err(format!("expected PayloadMismatch, got {other:?}")),
        }
        // Same property on the response side, against an Ok frame.
        let resp = ResponseFrame {
            id: rng.next_u64(),
            body: ResponseBody::Ok {
                output: Output::F32(vec![0.5; rng.range_usize(1, 8)]),
                latency_us: 1,
                batch: 1,
            },
        };
        let rbuf = encode_resp(&resp);
        let rbody = &rbuf[LEN_PREFIX..];
        match frame::decode_response(&rbody[..rbody.len() - chop]) {
            Err(FrameError::PayloadMismatch { .. }) => Ok(()),
            other => Err(format!("response: expected PayloadMismatch, got {other:?}")),
        }
    });
}

#[test]
fn corrupt_response_headers_yield_typed_errors() {
    check("response header corruption", 120, |rng| {
        let resp = random_response(rng);
        let buf = encode_resp(&resp);
        let body = buf[LEN_PREFIX..].to_vec();

        // Kind 0 (a request kind) and kinds 8.. are unknown responses.
        let mut bad = body.clone();
        bad[5] = if rng.below(2) == 0 { 0 } else { 8 + rng.below(248) as u8 };
        ensure(
            matches!(frame::decode_response(&bad), Err(FrameError::BadKind { .. })),
            "bad response kind not rejected",
        )?;

        // A Timeout frame must carry no payload.
        let timeout = ResponseFrame {
            id: 9,
            body: ResponseBody::Timeout { waited_us: 5, budget_us: 3 },
        };
        let mut tbuf = Vec::new();
        frame::encode_response(&timeout, &mut tbuf);
        tbuf.extend_from_slice(&[0, 0, 0, 0]);
        // Patch the length prefix to claim the padded bytes.
        let padded = (tbuf.len() - LEN_PREFIX) as u32;
        tbuf[..LEN_PREFIX].copy_from_slice(&padded.to_le_bytes());
        let (tbody, _) = frame::split_frame(&tbuf, DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        ensure(
            matches!(
                frame::decode_response(tbody),
                Err(FrameError::PayloadMismatch { .. })
            ),
            "padded Timeout frame not rejected",
        )?;

        // Non-UTF-8 detail text in an error kind.
        let shed = ResponseFrame { id: 3, body: ResponseBody::Shed { detail: "full".into() } };
        let mut sbuf = Vec::new();
        frame::encode_response(&shed, &mut sbuf);
        let at = LEN_PREFIX + RESPONSE_HEADER;
        sbuf[at] = 0xFF;
        let (sbody, _) = frame::split_frame(&sbuf, DEFAULT_MAX_FRAME).map_err(|e| e.to_string())?;
        ensure(
            matches!(frame::decode_response(sbody), Err(FrameError::BadText)),
            "non-UTF-8 detail not rejected",
        )
    });
}

#[test]
fn oversized_length_prefixes_are_rejected_from_four_bytes() {
    check("oversized prefix", 100, |rng| {
        // Any declared length above the cap — up to u32::MAX — must be
        // rejected from the prefix alone, even when no body follows.
        let limit = rng.range_usize(16, 4096);
        let declared = (limit as u64 + 1 + rng.below(1 << 20) as u64).min(u32::MAX as u64);
        let mut buf = (declared as u32).to_le_bytes().to_vec();
        // Sometimes append garbage "body" bytes; they must stay unread.
        if rng.below(2) == 0 {
            buf.extend_from_slice(&[0xAB; 8]);
        }
        match frame::split_frame(&buf, limit) {
            Err(FrameError::Oversized { declared: d, limit: l }) => {
                ensure(d == declared && l == limit, "wrong Oversized accounting")
            }
            other => Err(format!("expected Oversized, got {other:?}")),
        }
    });
}

#[test]
fn payload_at_exactly_the_cap_fits_and_one_element_more_does_not() {
    let req = RequestFrame {
        id: 0x1DEA,
        tenant: 42,
        model: "emg-q7".into(),
        input: vec![0.5; 64],
    };
    let buf = encode_req(&req);
    // A cap of exactly the encoded body size admits the frame...
    let cap = buf.len() - LEN_PREFIX;
    let (body, consumed) = frame::split_frame(&buf, cap).unwrap();
    assert_eq!(consumed, buf.len());
    assert_eq!(frame::decode_request(body).unwrap(), req);
    // ...and one more payload element overflows it from the prefix.
    let bigger = RequestFrame { input: vec![0.5; 65], ..req };
    let buf2 = encode_req(&bigger);
    assert!(matches!(frame::split_frame(&buf2, cap), Err(FrameError::Oversized { .. })));
}

#[test]
fn empty_payloads_and_empty_details_round_trip() {
    let req = RequestFrame { id: 0, tenant: 0, model: "m".into(), input: Vec::new() };
    let buf = encode_req(&req);
    assert_eq!(buf.len(), LEN_PREFIX + REQUEST_HEADER + 1);
    let (body, _) = frame::split_frame(&buf, DEFAULT_MAX_FRAME).unwrap();
    assert_eq!(frame::decode_request(body).unwrap(), req);

    for resp in [
        ResponseFrame {
            id: 1,
            body: ResponseBody::Ok { output: Output::F32(Vec::new()), latency_us: 0, batch: 1 },
        },
        ResponseFrame { id: 2, body: ResponseBody::Aborted { detail: String::new() } },
    ] {
        let rbuf = encode_resp(&resp);
        let (rbody, _) = frame::split_frame(&rbuf, DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(frame::decode_response(rbody).unwrap(), resp);
    }
}
