//! Batch/single-sample consistency: `run_batch` over N samples must
//! equal N independent `run` calls **exactly** (float: bit-identical,
//! the batched kernels preserve per-sample accumulation order) and
//! **bit-exactly** (fixed point), for every kernel implementation and
//! for the parallel batch driver at every thread count.

use fann_on_mcu::bench::batch::{run_batch_parallel, run_batch_parallel_with_kernel, run_batch_q_parallel};
use fann_on_mcu::fann::{Activation, FixedNetwork, Network};
use fann_on_mcu::kernels;
use fann_on_mcu::quantize;
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

fn random_net(rng: &mut Rng) -> Network {
    let n_layers = rng.range_usize(2, 4);
    let mut sizes = Vec::with_capacity(n_layers + 1);
    for _ in 0..=n_layers {
        sizes.push(rng.range_usize(1, 24));
    }
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(rng, None);
    net
}

#[test]
fn float_batch_equals_independent_runs_for_every_kernel() {
    check("float batch == singles", 60, |rng| {
        let net = random_net(rng);
        let n_in = net.num_inputs();
        let n_out = net.num_outputs();
        let n = rng.range_usize(1, 16);
        let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        for kernel in kernels::f32_kernels() {
            let batched = net.run_batch_with_kernel(kernel, &xs, n);
            ensure(
                batched.len() == n * n_out,
                format!("{}: bad output length", kernel.name()),
            )?;
            for s in 0..n {
                let single = net.run_with_kernel(kernel, &xs[s * n_in..(s + 1) * n_in]);
                ensure(
                    batched[s * n_out..(s + 1) * n_out] == single[..],
                    format!("{} sample {s}: batched != single", kernel.name()),
                )?;
            }
        }
        // The default-kernel convenience entry points agree too.
        let batched = net.run_batch(&xs, n);
        for s in 0..n {
            let single = net.run(&xs[s * n_in..(s + 1) * n_in]);
            ensure(
                batched[s * n_out..(s + 1) * n_out] == single[..],
                format!("default kernel sample {s}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn fixed_batch_bit_exact_vs_independent_runs() {
    check("fixed batch == singles", 60, |rng| {
        let net = random_net(rng);
        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let n_in = fixed.num_inputs();
        let n_out = fixed.num_outputs();
        let n = rng.range_usize(1, 16);
        let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| quantize::quantize(v, fixed.decimal_point))
            .collect();
        let batched = fixed.run_batch_q(&q, n);
        for s in 0..n {
            let single = fixed.run_q(&q[s * n_in..(s + 1) * n_in]);
            ensure(
                batched[s * n_out..(s + 1) * n_out] == single[..],
                format!("run_batch_q sample {s}"),
            )?;
        }
        // Float-in/float-out wrapper (quantize + infer + dequantize).
        let fbatched = fixed.run_batch(&xs, n);
        for s in 0..n {
            let single = fixed.run(&xs[s * n_in..(s + 1) * n_in]);
            ensure(
                fbatched[s * n_out..(s + 1) * n_out] == single[..],
                format!("run_batch sample {s}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn parallel_driver_matches_serial_at_every_thread_count() {
    check("parallel == serial", 30, |rng| {
        let net = random_net(rng);
        let n_in = net.num_inputs();
        let n = rng.range_usize(1, 40);
        let xs: Vec<f32> = (0..n * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let serial = net.run_batch(&xs, n);
        for threads in [1usize, 2, 3, 4, 7] {
            let par = run_batch_parallel(&net, &xs, n, threads);
            ensure(par == serial, format!("threads={threads}"))?;
            for kernel in kernels::f32_kernels() {
                let park = run_batch_parallel_with_kernel(&net, kernel, &xs, n, threads);
                let serk = net.run_batch_with_kernel(kernel, &xs, n);
                ensure(
                    park == serk,
                    format!("kernel {} threads={threads}", kernel.name()),
                )?;
            }
        }

        let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
        let q: Vec<i32> = xs
            .iter()
            .map(|&v| quantize::quantize(v, fixed.decimal_point))
            .collect();
        let serial_q = fixed.run_batch_q(&q, n);
        for threads in [1usize, 2, 5] {
            ensure(
                run_batch_q_parallel(&fixed, &q, n, threads) == serial_q,
                format!("fixed threads={threads}"),
            )?;
        }
        Ok(())
    });
}
