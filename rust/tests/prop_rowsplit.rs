//! Property test for the neuron-parallel (row-split) execution path:
//! over random network shapes, every kernel family (f32, q32, packed
//! q7/q15) and every core count 1..=8, the row-split driver must be
//! **bit-exact** vs the serial compiled-plan run — which the exec-plan
//! suite in turn pins to the dispatch paths. Shapes deliberately
//! include single-neuron layers and layers smaller than the core count
//! (ragged splits, idle cores), and batch sizes of 1 (the in-place
//! write path) and >1 (the scatter path).

use fann_on_mcu::bench::batch::{run_plan_q_rowsplit, run_plan_rowsplit};
use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::{ExecPlan, PackedWidth};
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

/// Random layer sizes: depth 2..=4 transitions, widths 1..=33 with a
/// bias toward tiny layers so single-neuron and sub-core-count layers
/// appear often.
fn random_sizes(rng: &mut Rng) -> Vec<usize> {
    let depth = rng.range_usize(2, 4);
    (0..=depth)
        .map(|_| {
            if rng.below(4) == 0 {
                rng.range_usize(1, 7) // tiny: often < 8 cores, sometimes 1
            } else {
                rng.range_usize(1, 33)
            }
        })
        .collect()
}

#[test]
fn rowsplit_bit_exact_across_shapes_cores_and_families() {
    check("row-split parity", 20, |rng| {
        let sizes = random_sizes(rng);
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)
            .map_err(|e| e.to_string())?;
        net.randomize(rng, None);
        let n_in = sizes[0];
        let n_samples = if rng.below(2) == 0 { 1 } else { rng.range_usize(2, 9) };
        let xs: Vec<f32> = (0..n_samples * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();

        // f32 family.
        let plan_f = ExecPlan::compile(&net);
        let want_f = plan_f.run_batch_f32(&xs, n_samples);
        ensure(
            want_f == net.run_batch(&xs, n_samples),
            format!("{sizes:?}: f32 plan diverged from dispatch"),
        )?;
        for cores in 1..=8usize {
            let got = run_plan_rowsplit(&plan_f, &xs, n_samples, cores);
            ensure(
                got == want_f,
                format!("{sizes:?}: f32 row-split diverged at {cores} cores, n={n_samples}"),
            )?;
        }

        // q32 family.
        let fixed = FixedNetwork::from_float(&net, 1.0).map_err(|e| e.to_string())?;
        let plan_q = ExecPlan::compile(&fixed);
        let xq = fixed.quantize_input(&xs);
        let want_q = plan_q.run_batch_q(&xq, n_samples);
        ensure(
            want_q == fixed.run_batch_q(&xq, n_samples),
            format!("{sizes:?}: q32 plan diverged from dispatch"),
        )?;
        for cores in 1..=8usize {
            let got = run_plan_q_rowsplit(&plan_q, &xq, n_samples, cores);
            ensure(
                got == want_q,
                format!("{sizes:?}: q32 row-split diverged at {cores} cores, n={n_samples}"),
            )?;
        }

        // Packed families (panel-aligned splits).
        for width in [PackedWidth::Q7, PackedWidth::Q15] {
            let (_, packed) = from_float_packed(&net, 1.0, width).map_err(|e| e.to_string())?;
            let plan_p = ExecPlan::compile(&packed);
            let xqp = packed.quantize_input(&xs);
            let want_p = plan_p.run_batch_q(&xqp, n_samples);
            ensure(
                want_p == packed.run_batch_q(&xqp, n_samples),
                format!("{sizes:?}: {width:?} plan diverged from dispatch"),
            )?;
            for cores in 1..=8usize {
                let got = run_plan_q_rowsplit(&plan_p, &xqp, n_samples, cores);
                ensure(
                    got == want_p,
                    format!(
                        "{sizes:?}: {width:?} row-split diverged at {cores} cores, n={n_samples}"
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn rowsplit_handles_degenerate_layers_exhaustively() {
    // Deterministic corner shapes: single-neuron output, every layer
    // smaller than 8 cores, and a single-panel packed layer.
    for sizes in [vec![3usize, 1], vec![5, 2, 1], vec![4, 3, 2, 1], vec![9, 4, 3]] {
        let mut rng = Rng::new(0xD_E9E0);
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
        net.randomize(&mut rng, None);
        let n_in = sizes[0];
        for n_samples in [1usize, 3] {
            let xs: Vec<f32> =
                (0..n_samples * n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let plan_f = ExecPlan::compile(&net);
            let want = plan_f.run_batch_f32(&xs, n_samples);
            let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
            let plan_q = ExecPlan::compile(&fixed);
            let xq = fixed.quantize_input(&xs);
            let want_q = plan_q.run_batch_q(&xq, n_samples);
            for cores in 1..=8usize {
                assert_eq!(
                    run_plan_rowsplit(&plan_f, &xs, n_samples, cores),
                    want,
                    "{sizes:?} cores={cores} n={n_samples}"
                );
                assert_eq!(
                    run_plan_q_rowsplit(&plan_q, &xq, n_samples, cores),
                    want_q,
                    "{sizes:?} cores={cores} n={n_samples}"
                );
            }
        }
    }
}
