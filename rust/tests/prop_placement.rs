//! Property tests for the placement policy and the detailed deploy
//! plan: for random networks and random memory budgets,
//!
//! * the chosen placement never oversubscribes the budget it claims to
//!   fit (L1 / L2 / RAM / flash);
//! * every weight/activation buffer is placed exactly once in the
//!   detailed plan, and the DMA double-buffer schedule covers every
//!   layer that does not fit L1;
//! * oversized networks produce a structured error (`NoFit` from the
//!   policy, `Err` from the plan builder) — never a panic.

use fann_on_mcu::codegen::{build_deploy_plan, emit_float, NetRepr};
use fann_on_mcu::deploy::{
    self, cluster_l1_budget, estimate_memory, place_cluster_with, place_cortex_with,
    place_fc_with, DmaStrategy, NetShape,
};
use fann_on_mcu::fann::{Activation, Network};
use fann_on_mcu::targets::{DataType, Region, Target};
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

fn random_shape(rng: &mut Rng) -> NetShape {
    let n_layers = rng.range_usize(2, 5);
    let sizes: Vec<usize> = (0..n_layers).map(|_| rng.range_usize(1, 300)).collect();
    NetShape::new(&sizes)
}

fn random_dtype(rng: &mut Rng) -> DataType {
    if rng.below(2) == 0 {
        DataType::Float32
    } else {
        DataType::Fixed
    }
}

#[test]
fn cluster_placement_never_oversubscribes_budgets() {
    check("cluster placement respects budgets", 400, |rng| {
        let shape = random_shape(rng);
        let dtype = random_dtype(rng);
        let l1 = rng.range_usize(1, 160) * 1024;
        let l2 = rng.range_usize(1, 600) * 1024;
        let est = estimate_memory(&shape, dtype);
        let (region, dma) = place_cluster_with(&shape, dtype, est, l1, l2);
        match (region, dma) {
            (Region::L1, None) => ensure(est <= l1, format!("L1: est {est} > budget {l1}")),
            (Region::L1, Some(_)) => Err("L1-resident must not stream".into()),
            (Region::SharedL2, Some(DmaStrategy::LayerWise)) => {
                ensure(
                    shape.param_bytes(dtype) <= l2
                        && 2 * shape.max_layer_param_bytes(dtype) <= l1,
                    "layer-wise double buffer exceeds budgets",
                )
            }
            (Region::SharedL2, Some(DmaStrategy::NeuronWise)) => ensure(
                shape.param_bytes(dtype) <= l2 && 2 * shape.max_neuron_row_bytes(dtype) <= l1,
                "neuron-wise double buffer exceeds budgets",
            ),
            (Region::SharedL2, None) => Err("cluster L2 placement must stream".into()),
            (Region::NoFit, None) => {
                // NoFit must be genuine: no policy would have accepted it.
                ensure(
                    est > l1
                        && (shape.param_bytes(dtype) > l2
                            || 2 * shape.max_neuron_row_bytes(dtype) > l1),
                    "NoFit despite a feasible policy",
                )
            }
            other => Err(format!("impossible cluster placement {other:?}")),
        }
    });
}

#[test]
fn fc_and_cortex_placements_respect_budgets() {
    check("fc/cortex placements respect budgets", 400, |rng| {
        let shape = random_shape(rng);
        let dtype = random_dtype(rng);
        let est = estimate_memory(&shape, dtype);

        let private = rng.range_usize(1, 128) * 1024;
        let shared = rng.range_usize(1, 512) * 1024;
        match place_fc_with(est, private, shared) {
            (Region::PrivateL2, None) => ensure(est <= private, "private L2 oversubscribed")?,
            (Region::SharedL2, None) => {
                ensure(est > private && est <= shared, "shared L2 misplaced")?
            }
            (Region::NoFit, None) => ensure(est > shared, "FC NoFit despite fitting")?,
            other => return Err(format!("impossible FC placement {other:?}")),
        }

        let ram = rng.range_usize(1, 256) * 1024;
        let flash = rng.range_usize(1, 2048) * 1024;
        match place_cortex_with(&shape, dtype, est, ram, flash) {
            (Region::Ram, None) => ensure(est <= ram, "RAM oversubscribed")?,
            (Region::Flash, None) => {
                let params = shape.param_bytes(dtype);
                let runtime = est - shape.num_weights() * 4;
                ensure(
                    est > ram && params <= flash && runtime <= ram,
                    "flash split oversubscribed",
                )?
            }
            (Region::NoFit, None) => ensure(est > ram, "cortex NoFit despite fitting RAM")?,
            other => return Err(format!("impossible cortex placement {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn detailed_plan_places_every_layer_exactly_once_and_dma_covers_l1_misfits() {
    check("detailed plan invariants", 60, |rng| {
        let n_layers = rng.range_usize(2, 4);
        let sizes: Vec<usize> = (0..n_layers).map(|_| rng.range_usize(1, 220)).collect();
        let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid)
            .map_err(|e| e.to_string())?;
        net.randomize(rng, None);
        let bundle = match emit_float(&net, Target::WolfCluster { cores: 8 }, NetRepr::F32, 1.0)
        {
            Ok(b) => b,
            // Structured no-fit / oversubscription errors are a legal
            // outcome of random shapes — the property is "no panic".
            Err(_) => return Ok(()),
        };
        let plan = &bundle.artifact.plan;

        // Every dense layer appears exactly once, in order.
        ensure(plan.layers.len() == sizes.len() - 1, "layer count mismatch")?;
        for (i, l) in plan.layers.iter().enumerate() {
            ensure(l.index == i, format!("layer {i} indexed as {}", l.index))?;
            ensure(
                l.n_in == sizes[i] && l.n_out == sizes[i + 1],
                format!("layer {i} shape mismatch"),
            )?;
            ensure(l.param_bytes == (sizes[i] * sizes[i + 1] + sizes[i + 1]) * 4,
                format!("layer {i} byte count mismatch"))?;
        }

        let budget = cluster_l1_budget();
        match plan.region {
            Region::L1 => {
                ensure(plan.dma.is_none(), "L1-resident plan must not stream")?;
                ensure(
                    plan.param_bytes() + plan.activation_buffer_bytes() <= budget,
                    "L1-resident plan oversubscribes the budget",
                )?;
            }
            Region::SharedL2 => {
                // The schedule covers ALL layers (a fortiori every layer
                // that does not fit L1), and its staging fits L1.
                for l in &plan.layers {
                    let dma = l.dma.as_ref().ok_or("L2-resident layer without DMA")?;
                    ensure(dma.chunks >= 1, "empty DMA schedule")?;
                    ensure(
                        dma.chunks * dma.chunk_bytes >= l.param_bytes,
                        "DMA schedule moves fewer bytes than the layer holds",
                    )?;
                    ensure(l.compute_region == Region::L1, "streamed layer computes from L2")?;
                }
                ensure(
                    plan.staging_bytes() + plan.activation_buffer_bytes() <= budget,
                    "staging oversubscribes L1",
                )?;
            }
            other => return Err(format!("unexpected cluster region {other:?}")),
        }
        Ok(())
    });
}

#[test]
fn oversized_networks_error_structurally_not_by_panic() {
    // Far over every memory: placement reports NoFit, the plan builder
    // and the emit pipeline return errors with actionable messages.
    let shape = NetShape::new(&[2048, 2048, 8]);
    let p = deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
    assert_eq!(p.region, Region::NoFit);
    let acts = [Activation::Tanh, Activation::Sigmoid];
    let bytes: Vec<usize> = shape
        .sizes
        .windows(2)
        .map(|w| (w[0] * w[1] + w[1]) * 4)
        .collect();
    let err = build_deploy_plan(&p, NetRepr::F32, None, &acts, &bytes).unwrap_err();
    assert!(err.to_string().contains("does not fit"), "{err}");
}
