//! CLI integration tests: run the built `fann-on-mcu` binary end to end
//! (train → deploy → run) through a temp directory.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_fann-on-mcu"))
}

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fann_on_mcu_cli_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["train", "deploy", "run", "info", "train-pjrt"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn info_lists_apps() {
    let out = bin().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("gesture") && text.contains("fall") && text.contains("activity"));
}

#[test]
fn throughput_runs_and_reports_all_paths() {
    let out = bin()
        .args([
            "throughput", "--topo", "8,8,4", "--samples", "64", "--reps", "1", "--threads", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "run_batch()",
        "parallel driver",
        "run_q()",
        "vs loop",
        "exec plan",
        "exec plan row-split",
    ] {
        assert!(text.contains(needle), "throughput output missing {needle:?}:\n{text}");
    }
}

#[test]
fn bench_json_writes_perf_baseline() {
    let dir = tmpdir("benchjson");
    let out_path = dir.join("BENCH_kernels.json");
    let out = bin()
        .args([
            "bench", "json", "--topo", "8,8,4", "--samples", "32", "--reps", "1", "--threads",
            "2", "--out", out_path.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "bench json failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = std::fs::read_to_string(&out_path).unwrap();
    for needle in [
        "\"schema\": \"fann-on-mcu/bench-kernels/v1\"",
        "\"kernel\": \"packed_q7\"",
        "\"kernel\": \"packed_q15\"",
        "\"kernel\": \"fixed_q\"",
        "\"kernel\": \"scalar_f32\"",
        "\"kernel\": \"blocked_f32\"",
        "\"mode\": \"parallel\"",
        "\"bytes_per_network\"",
        "speedup_packed_q7_vs_fixed_q_serial",
        // Compiled-plan rows + the two new speedup gates.
        "\"kernel\": \"exec_plan_f32\"",
        "\"kernel\": \"exec_plan_q32\"",
        "\"kernel\": \"exec_plan_q7\"",
        "\"kernel\": \"exec_plan_q15\"",
        "\"mode\": \"rowsplit\"",
        "speedup_execplan_vs_dispatch_serial",
        "speedup_rowsplit_8w_vs_serial",
        "\"fig11_rowsplit\"",
        "\"workers_requested\": 8",
        // Per-target emulated cycle counts (the CI bench-smoke gate).
        "\"emulated\"",
        "\"target\": \"cortex-m4f\"",
        "\"target\": \"wolf-8core\"",
        "\"repr\": \"q15\"",
        "\"emulated_cycles\"",
    ] {
        assert!(text.contains(needle), "bench json missing {needle:?}:\n{text}");
    }
    // Unknown bench mode is rejected.
    let out = bin().args(["bench", "csv"]).output().unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_smoke_asserts_rowsplit_checksum_parity() {
    let out = bin().args(["bench", "smoke", "--samples", "24"]).output().unwrap();
    assert!(
        out.status.success(),
        "bench smoke failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("all checksum-identical to serial"),
        "bench smoke output:\n{text}"
    );
}

#[test]
fn deploy_emit_and_emulate_acceptance_targets() {
    let dir = tmpdir("emit");
    for target in ["cortex-m4f", "wolf-8core"] {
        let gen_dir = dir.join(target);
        let out = bin()
            .args([
                "deploy", "emit", "--target", target, "--topo", "12,10,4", "--out",
                gen_dir.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "deploy emit --target {target} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        for file in ["fann_conf.h", "fann_net.h", "fann_inner_loop.c", "fann_run.c", "deploy_plan.json"] {
            assert!(gen_dir.join(file).exists(), "{target}: missing {file}");
        }
        let plan = std::fs::read_to_string(gen_dir.join("deploy_plan.json")).unwrap();
        assert!(plan.contains("\"schema\": \"fann-on-mcu/deploy-plan/v1\""));
        assert!(plan.contains(&format!("\"target\": \"{target}\"")));

        let out = bin()
            .args(["deploy", "emulate", "--target", target, "--topo", "12,10,4"])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "deploy emulate --target {target} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("OK (bit-exact)"), "{target}: no parity line:\n{text}");
        assert!(text.contains("predicted class"));
    }

    // A network that exceeds cluster L1 exercises the DMA schedule
    // through the CLI path too.
    let out = bin()
        .args(["deploy", "emulate", "--target", "wolf-8core", "--topo", "600,40,8"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "DMA emulate failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("OK (bit-exact)"));
    assert!(text.contains("DMA transfers"));

    // Unknown deploy mode is rejected.
    let out = bin().args(["deploy", "frobnicate"]).output().unwrap();
    assert!(!out.status.success());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_command_fails_with_help() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn unknown_flag_rejected() {
    let out = bin().args(["train", "--ap", "fall"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
}

#[test]
fn train_deploy_run_roundtrip() {
    let dir = tmpdir("roundtrip");
    let prefix = dir.join("activity");
    let prefix_s = prefix.to_str().unwrap();

    // train + save
    let out = bin()
        .args(["train", "--app", "activity", "--seed", "7", "--out", prefix_s])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(prefix.with_extension("net").exists());
    let fixed_net = dir.join("activity_fixed.net");
    assert!(fixed_net.exists());

    // deploy the fixed net to the FC, writing generated C
    let gen_dir = dir.join("gen");
    let out = bin()
        .args([
            "deploy",
            "--net",
            fixed_net.to_str().unwrap(),
            "--target",
            "ibex",
            "--out",
            gen_dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "deploy failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(gen_dir.join("fann_conf.h").exists());
    assert!(gen_dir.join("fann_inner_loop.c").exists());

    // run one classification on the cluster
    let input = vec!["0.1"; 7].join(",");
    let out = bin()
        .args([
            "run",
            "--net",
            prefix.with_extension("net").to_str().unwrap(),
            "--target",
            "cluster8",
            "--input",
            &input,
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("predicted class"));
    assert!(text.contains("energy/classification"));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_rejects_float_net_on_fpu_less_target() {
    let dir = tmpdir("fpu");
    let prefix = dir.join("fall");
    let out = bin()
        .args(["train", "--app", "fall", "--seed", "3", "--out", prefix.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let input = vec!["0.0"; 117].join(",");
    let out = bin()
        .args([
            "run",
            "--net",
            prefix.with_extension("net").to_str().unwrap(),
            "--target",
            "ibex",
            "--input",
            &input,
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fixed-point"));
    std::fs::remove_dir_all(&dir).ok();
}
