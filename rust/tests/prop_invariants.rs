//! Property-based tests over the coordinator invariants: placement,
//! memory estimation, quantization, simulation cost model, file formats.
//! Driven by the hand-rolled `util::proptest` driver (deterministic
//! seeds, replayable failures).

use fann_on_mcu::deploy::{self, estimate_memory, NetShape};
use fann_on_mcu::fann::{io, Activation, FixedNetwork, Network, TrainData};
use fann_on_mcu::quantize;
use fann_on_mcu::simulator::cost::{self, CostOptions};
use fann_on_mcu::targets::{memspec, Chip, DataType, Region, Target};
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

fn random_shape(rng: &mut Rng) -> NetShape {
    let n_hidden = rng.range_usize(1, 4);
    let mut sizes = vec![rng.range_usize(1, 256)];
    for _ in 0..n_hidden {
        sizes.push(rng.range_usize(1, 256));
    }
    sizes.push(rng.range_usize(1, 32));
    NetShape::new(&sizes)
}

fn random_net(rng: &mut Rng, max_width: usize) -> Network {
    let n_hidden = rng.range_usize(1, 3);
    let mut sizes = vec![rng.range_usize(1, max_width)];
    for _ in 0..n_hidden {
        sizes.push(rng.range_usize(1, max_width));
    }
    sizes.push(rng.range_usize(1, 8));
    let mut net = Network::new(&sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    net.randomize(rng, None);
    net
}

fn acts(n: usize) -> Vec<Activation> {
    let mut v = vec![Activation::Tanh; n - 1];
    v.push(Activation::Sigmoid);
    v
}

#[test]
fn placement_always_fits_or_nofit() {
    // Whatever the shape, a plan that claims a region must actually fit
    // in that region's capacity.
    check("placement fits", 300, |rng| {
        let shape = random_shape(rng);
        let target = match rng.below(4) {
            0 => Target::CortexM4(Chip::Stm32l475vg),
            1 => Target::CortexM4(Chip::Nrf52832),
            2 => Target::WolfFc,
            _ => Target::WolfCluster {
                cores: rng.range_usize(1, 8) as u32,
            },
        };
        let dtype = if target.supports_float() && rng.below(2) == 0 {
            DataType::Float32
        } else {
            DataType::Fixed
        };
        let plan = deploy::plan(&shape, target, dtype).map_err(|e| e.to_string())?;
        let est = plan.est_memory_bytes;
        let wolf = memspec::WOLF_MEMORY;
        match plan.region {
            Region::Ram => {
                let chip = match target {
                    Target::CortexM4(c) | Target::CortexM0(c) => c,
                    _ => return Err("RAM region on non-cortex target".into()),
                };
                ensure(est <= chip.memory().ram, "RAM overflow")
            }
            Region::Flash => {
                let chip = match target {
                    Target::CortexM4(c) | Target::CortexM0(c) => c,
                    _ => return Err("flash region on non-cortex target".into()),
                };
                ensure(
                    shape.param_bytes(dtype) <= chip.memory().flash,
                    "flash overflow",
                )
            }
            Region::PrivateL2 => ensure(est <= wolf.private_l2, "private L2 overflow"),
            Region::SharedL2 => match target {
                Target::WolfFc => ensure(est <= wolf.shared_l2, "shared L2 overflow"),
                Target::WolfCluster { .. } => {
                    ensure(shape.param_bytes(dtype) <= wolf.shared_l2, "shared L2 overflow")
                }
                _ => Err("shared L2 on non-wolf target".into()),
            },
            Region::L1 => ensure(est <= wolf.l1, "L1 overflow"),
            Region::NoFit => Ok(()),
        }
    });
}

#[test]
fn dma_only_when_l2_resident_on_cluster() {
    check("dma iff streaming", 200, |rng| {
        let shape = random_shape(rng);
        let plan = deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Fixed)
            .map_err(|e| e.to_string())?;
        match plan.region {
            Region::L1 | Region::NoFit => ensure(plan.dma.is_none(), "unexpected DMA"),
            Region::SharedL2 => ensure(plan.dma.is_some(), "missing DMA strategy"),
            r => Err(format!("unexpected region {r:?}")),
        }
    });
}

#[test]
fn eq2_estimate_dominates_raw_parameters() {
    // The Eq. 2 estimate must upper-bound the raw parameter bytes
    // (it adds buffers + bookkeeping) and grow monotonically with width.
    check("eq2 bounds", 300, |rng| {
        let shape = random_shape(rng);
        let dtype = if rng.below(2) == 0 {
            DataType::Float32
        } else {
            DataType::Fixed
        };
        let est = estimate_memory(&shape, dtype);
        ensure(est >= shape.param_bytes(dtype), "estimate below raw params")?;
        // widening any single hidden layer cannot shrink the estimate
        let mut wider = shape.sizes.clone();
        let l = rng.range_usize(1, wider.len() - 1);
        wider[l] += rng.range_usize(1, 64);
        let est2 = estimate_memory(&NetShape::new(&wider), dtype);
        ensure(est2 >= est, "estimate not monotone")
    });
}

#[test]
fn parallel_cycles_bounded_by_core_count() {
    // p cores can never speed a network up by more than p; and multi-core
    // can only be *slower* than single-core by the explicit parallel
    // overheads (per-layer barrier + streaming contention) — the same
    // "parallelization overhead" effect the paper reports for tiny nets.
    check("parallel bounds", 200, |rng| {
        let shape = random_shape(rng);
        let a = acts(shape.sizes.len() - 1);
        let single = deploy::plan(&shape, Target::WolfCluster { cores: 1 }, DataType::Fixed)
            .map_err(|e| e.to_string())?;
        let cores = rng.range_usize(2, 8) as u32;
        let multi = deploy::plan(&shape, Target::WolfCluster { cores }, DataType::Fixed)
            .map_err(|e| e.to_string())?;
        if !single.fits() || !multi.fits() {
            return Ok(());
        }
        let s = cost::network_cycles(&single, &a, CostOptions::default()).total();
        let m = cost::network_cycles(&multi, &a, CostOptions::default()).total();
        let overhead_allowance =
            a.len() as f64 * cost::BARRIER_CYCLES + s * cost::STREAM_CONTENTION_PER_CORE * 7.0;
        ensure(
            m <= s + overhead_allowance,
            format!("multi slower beyond overheads: {m} vs {s}"),
        )?;
        ensure(
            s / m <= cores as f64 + 1e-9,
            format!("superlinear speedup {}x on {cores} cores", s / m),
        )
    });
}

#[test]
fn legacy_init_never_faster() {
    check("legacy slower", 150, |rng| {
        let shape = random_shape(rng);
        let a = acts(shape.sizes.len() - 1);
        let plan = deploy::plan(&shape, Target::CortexM4(Chip::Stm32l475vg), DataType::Fixed)
            .map_err(|e| e.to_string())?;
        if !plan.fits() {
            return Ok(());
        }
        let new = cost::network_cycles(&plan, &a, CostOptions::default()).total();
        let old = cost::network_cycles(
            &plan,
            &a,
            CostOptions {
                legacy_init: true,
                ..CostOptions::default()
            },
        )
        .total();
        ensure(old >= new, "legacy init faster than optimized")
    });
}

#[test]
fn quantize_dequantize_error_bounded() {
    check("quantize error", 400, |rng| {
        let dec = rng.range_usize(4, 20) as u32;
        let v = rng.range_f32(-100.0, 100.0);
        let q = quantize::quantize(v, dec);
        let back = quantize::dequantize(q as i64, dec);
        let lsb = 1.0 / (1i64 << dec) as f32;
        ensure(
            (v - back).abs() <= lsb,
            format!("dec={dec} v={v} back={back}"),
        )
    });
}

#[test]
fn fixed_net_tracks_float_net() {
    // Random small nets: quantized outputs stay within the step-linear
    // approximation band of the float outputs.
    check("fixed tracks float", 60, |rng| {
        let net = random_net(rng, 24);
        let fixed = FixedNetwork::from_float(&net, 1.0).map_err(|e| e.to_string())?;
        let x: Vec<f32> = (0..net.num_inputs())
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        let yf = net.run(&x);
        let yq = fixed.run(&x);
        for (a, b) in yf.iter().zip(&yq) {
            ensure(
                (a - b).abs() < 0.15,
                format!("float {a} vs fixed {b} (dec={})", fixed.decimal_point),
            )?;
        }
        Ok(())
    });
}

#[test]
fn net_file_roundtrip_preserves_inference() {
    check("net io roundtrip", 40, |rng| {
        let net = random_net(rng, 16);
        let back = io::load_float(&io::save_float(&net)).map_err(|e| e.to_string())?;
        let x: Vec<f32> = (0..net.num_inputs())
            .map(|_| rng.range_f32(-1.0, 1.0))
            .collect();
        ensure(net.run(&x) == back.run(&x), "roundtrip changed outputs")
    });
}

#[test]
fn data_file_roundtrip() {
    check("data io roundtrip", 40, |rng| {
        let n_in = rng.range_usize(1, 8);
        let n_out = rng.range_usize(1, 4);
        let mut d = TrainData::new(n_in, n_out);
        for _ in 0..rng.range_usize(1, 12) {
            let x: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-5.0, 5.0)).collect();
            let y: Vec<f32> = (0..n_out).map(|_| rng.range_f32(0.0, 1.0)).collect();
            d.push(&x, &y);
        }
        let back = TrainData::from_fann_format(&d.to_fann_format()).map_err(|e| e.to_string())?;
        ensure(back.inputs == d.inputs && back.targets == d.targets, "roundtrip mismatch")
    });
}

#[test]
fn step_linear_tables_bounded_and_monotone() {
    check("q tables", 200, |rng| {
        let dec = rng.range_usize(4, 16) as u32;
        let one = 1i64 << dec;
        let a = rng.range_f32(-10.0, 10.0) as f64;
        let b = a + rng.uniform() * 4.0;
        let xa = (a * one as f64) as i64;
        let xb = (b * one as f64) as i64;
        let sa = quantize::step_linear_sigmoid_q(xa, dec);
        let sb = quantize::step_linear_sigmoid_q(xb, dec);
        ensure(sa <= sb, "sigmoid not monotone")?;
        ensure((0..=one).contains(&sa), "sigmoid out of range")?;
        let ta = quantize::step_linear_tanh_q(xa, dec);
        let tb = quantize::step_linear_tanh_q(xb, dec);
        ensure(ta <= tb, "tanh not monotone")?;
        ensure((-one..=one).contains(&ta), "tanh out of range")
    });
}
