//! Golden-vector differential harness for the emit→emulate pipeline.
//!
//! Two halves:
//!
//! 1. **Differential grid** — for a grid of architectures (ragged
//!    widths, every activation, f32/q32/q7/q15) the emulator's outputs
//!    on the emitted artifact must be **bit-exact** vs the native kernel
//!    path of the same representation (`FixedQ` via `FixedNetwork`,
//!    `PackedQ7`/`PackedQ15` via `PackedNetwork`, `BlockedF32` via
//!    `Network::run`) and within float tolerance vs `ScalarF32` — and
//!    the contract must hold through the DMA double-buffer schedules of
//!    networks that exceed cluster L1.
//! 2. **Emitted-C snapshots** — deterministic configurations are pinned
//!    against committed golden files under `rust/tests/golden/`;
//!    regenerate with `UPDATE_GOLDEN=1 cargo test --test golden_codegen`.

use std::path::PathBuf;

use fann_on_mcu::codegen::{emit_fixed, emit_float, NetRepr};
use fann_on_mcu::emulator::{emulate, emulate_q};
use fann_on_mcu::fann::activation::ALL as ALL_ACTS;
use fann_on_mcu::fann::fixed::FixedLayer;
use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::{PackedWidth, ScalarF32};
use fann_on_mcu::targets::{Chip, Target};
use fann_on_mcu::util::rng::Rng;

fn grid_net(sizes: &[usize], hidden: Activation, seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let mut net = Network::new(sizes, hidden, Activation::Sigmoid).unwrap();
    net.randomize(&mut rng, None);
    net
}

fn grid_input(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x1517);
    (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect()
}

/// Ragged shapes straddling the packed kernels' 4-lane / 4-row tiles.
const GRID_SHAPES: [&[usize]; 4] = [&[5, 7, 3], &[4, 6, 6, 2], &[3, 5, 1], &[9, 4, 2]];

#[test]
fn q32_emulation_bit_exact_across_grid() {
    for (si, &sizes) in GRID_SHAPES.iter().enumerate() {
        for (ai, &hidden) in ALL_ACTS.iter().enumerate() {
            let net = grid_net(sizes, hidden, 100 + (si * 7 + ai) as u64);
            let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
            let x = grid_input(sizes[0], si as u64);
            let xq = fixed.quantize_input(&x);
            let native = fixed.run_q(&xq);
            for target in [
                Target::WolfFc,
                Target::CortexM4(Chip::Nrf52832),
                Target::WolfCluster { cores: 8 },
            ] {
                let bundle = emit_fixed(&fixed, target).unwrap();
                let rep = emulate_q(&bundle.artifact, &xq).unwrap();
                assert_eq!(
                    rep.outputs_q.as_deref().unwrap(),
                    &native[..],
                    "sizes {sizes:?} hidden {hidden:?} target {target:?}"
                );
            }
        }
    }
}

#[test]
fn packed_emulation_bit_exact_across_grid() {
    for (si, &sizes) in GRID_SHAPES.iter().enumerate() {
        for (ai, &hidden) in ALL_ACTS.iter().enumerate() {
            let net = grid_net(sizes, hidden, 300 + (si * 7 + ai) as u64);
            for (width, repr) in [(PackedWidth::Q7, NetRepr::Q7), (PackedWidth::Q15, NetRepr::Q15)]
            {
                let (fixed_ref, packed) = from_float_packed(&net, 1.0, width).unwrap();
                let x = grid_input(sizes[0], 31 + si as u64);
                let xq = packed.quantize_input(&x);
                let native = packed.run_q(&xq);
                // Packed is itself pinned to the wide FixedQ reference.
                assert_eq!(native, fixed_ref.run_q(&xq), "{width:?} {sizes:?}");
                let bundle = emit_float(&net, Target::WolfCluster { cores: 8 }, repr, 1.0)
                    .unwrap();
                assert_eq!(bundle.artifact.plan.decimal_point, Some(packed.decimal_point));
                let rep = emulate_q(&bundle.artifact, &xq).unwrap();
                assert_eq!(
                    rep.outputs_q.as_deref().unwrap(),
                    &native[..],
                    "sizes {sizes:?} hidden {hidden:?} {width:?}"
                );
            }
        }
    }
}

#[test]
fn f32_emulation_bit_exact_vs_default_and_close_to_scalar() {
    for (si, &sizes) in GRID_SHAPES.iter().enumerate() {
        for (ai, &hidden) in ALL_ACTS.iter().enumerate() {
            let net = grid_net(sizes, hidden, 500 + (si * 7 + ai) as u64);
            let x = grid_input(sizes[0], 77 + si as u64);
            for target in [
                Target::CortexM4(Chip::Stm32l475vg),
                Target::WolfCluster { cores: 8 },
            ] {
                let bundle = emit_float(&net, target, NetRepr::F32, 1.0).unwrap();
                let rep = emulate(&bundle.artifact, &x).unwrap();
                // Bit-exact vs the default (BlockedF32) host path.
                assert_eq!(rep.outputs, net.run(&x), "sizes {sizes:?} {target:?}");
                // Within reassociation tolerance vs the scalar reference.
                let scalar = net.run_with_kernel(&ScalarF32, &x);
                for (a, b) in rep.outputs.iter().zip(&scalar) {
                    assert!(
                        (a - b).abs() < 3e-5,
                        "sizes {sizes:?} hidden {hidden:?}: {a} vs scalar {b}"
                    );
                }
            }
        }
    }
}

/// Layer-wise DMA: the whole network exceeds the cluster L1 budget but
/// every layer fits half of it.
#[test]
fn layerwise_dma_network_bit_exact_and_walks_schedule() {
    let sizes = [50usize, 100, 60, 100, 60, 8];
    let net = grid_net(&sizes, Activation::Tanh, 1234);
    let x = grid_input(50, 9);

    // Float on the cluster.
    let bundle = emit_float(&net, Target::WolfCluster { cores: 8 }, NetRepr::F32, 1.0).unwrap();
    assert_eq!(
        bundle.artifact.plan.dma,
        Some(fann_on_mcu::deploy::DmaStrategy::LayerWise)
    );
    let rep = emulate(&bundle.artifact, &x).unwrap();
    assert_eq!(rep.outputs, net.run(&x));
    assert_eq!(rep.dma_chunks, 5, "one transfer per dense layer");
    assert_eq!(rep.dma_bytes, bundle.artifact.plan.param_bytes());
    assert!(rep.breakdown.dma > 0.0);
    assert!(rep.l1_peak_bytes <= fann_on_mcu::deploy::cluster_l1_budget());

    // Quantized on the cluster: still bit-exact through the staged path.
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let bundle_q = emit_fixed(&fixed, Target::WolfCluster { cores: 8 }).unwrap();
    let xq = fixed.quantize_input(&x);
    let rep_q = emulate_q(&bundle_q.artifact, &xq).unwrap();
    assert_eq!(rep_q.outputs_q.as_deref().unwrap(), &fixed.run_q(&xq)[..]);
    assert_eq!(rep_q.dma_chunks, 5);
}

/// Neuron-wise DMA: a single layer exceeds L1, so the emulator slides a
/// two-row staging window — one transfer per output neuron.
#[test]
fn neuronwise_dma_network_bit_exact_and_walks_rows() {
    let sizes = [600usize, 40, 8];
    let net = grid_net(&sizes, Activation::Tanh, 4321);
    let x = grid_input(600, 5);

    let bundle = emit_float(&net, Target::WolfCluster { cores: 8 }, NetRepr::F32, 1.0).unwrap();
    assert_eq!(
        bundle.artifact.plan.dma,
        Some(fann_on_mcu::deploy::DmaStrategy::NeuronWise)
    );
    let rep = emulate(&bundle.artifact, &x).unwrap();
    assert_eq!(rep.outputs, net.run(&x));
    assert_eq!(rep.dma_chunks, 40 + 8, "one transfer per output neuron");
    assert!(rep.l1_peak_bytes <= fann_on_mcu::deploy::cluster_l1_budget());

    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let bundle_q = emit_fixed(&fixed, Target::WolfCluster { cores: 8 }).unwrap();
    let xq = fixed.quantize_input(&x);
    let rep_q = emulate_q(&bundle_q.artifact, &xq).unwrap();
    assert_eq!(rep_q.outputs_q.as_deref().unwrap(), &fixed.run_q(&xq)[..]);
    assert_eq!(rep_q.dma_chunks, 48);

    // Packed representation through the same neuron-wise schedule: the
    // emulator slides a panel-granular staging window and must stay
    // bit-exact vs the native packed network.
    for (width, repr) in [(PackedWidth::Q7, NetRepr::Q7), (PackedWidth::Q15, NetRepr::Q15)] {
        let (_, packed) = from_float_packed(&net, 1.0, width).unwrap();
        let bundle_p = emit_float(&net, Target::WolfCluster { cores: 8 }, repr, 1.0).unwrap();
        assert_eq!(
            bundle_p.artifact.plan.dma,
            Some(fann_on_mcu::deploy::DmaStrategy::NeuronWise),
            "{width:?}"
        );
        let xqp = packed.quantize_input(&x);
        let rep_p = emulate_q(&bundle_p.artifact, &xqp).unwrap();
        assert_eq!(
            rep_p.outputs_q.as_deref().unwrap(),
            &packed.run_q(&xqp)[..],
            "{width:?}"
        );
        assert_eq!(rep_p.dma_chunks, 48, "{width:?}");
    }
}

#[test]
fn emulated_cycles_match_plan_estimate_everywhere() {
    let net = grid_net(&[9, 6, 4], Activation::Tanh, 9);
    let fixed = FixedNetwork::from_float(&net, 1.0).unwrap();
    let x = grid_input(9, 1);
    for target in [
        Target::CortexM4(Chip::Nrf52832),
        Target::WolfFc,
        Target::WolfCluster { cores: 1 },
        Target::WolfCluster { cores: 8 },
    ] {
        let bundle = emit_fixed(&fixed, target).unwrap();
        let rep = emulate(&bundle.artifact, &x).unwrap();
        assert_eq!(
            rep.cycles(),
            bundle.artifact.plan.cost.breakdown.total(),
            "{target:?}"
        );
        assert_eq!(rep.energy_uj, bundle.artifact.plan.cost.energy_uj, "{target:?}");
    }
}

// ---------------------------------------------------------------------------
// Emitted-C snapshots
// ---------------------------------------------------------------------------

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name)
}

fn check_golden(name: &str, contents: &str) {
    let path = golden_path(name);
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, contents).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!("missing golden file {path:?} ({e}); run UPDATE_GOLDEN=1 cargo test --test golden_codegen to create it")
    });
    assert_eq!(
        contents, want,
        "emitted {name} diverged from the committed golden file; if the change is intentional, \
         regenerate with UPDATE_GOLDEN=1 cargo test --test golden_codegen"
    );
}

/// A hand-set fixed network whose emitted text is fully deterministic.
fn golden_fixed_net() -> FixedNetwork {
    FixedNetwork {
        layers: vec![FixedLayer {
            n_in: 3,
            n_out: 2,
            weights: vec![1, 2, 3, 4, 5, 6],
            biases: vec![7, 8],
            activation: Activation::Tanh,
        }],
        decimal_point: 4,
    }
}

#[test]
fn golden_m4_fixed_snapshots() {
    let fixed = golden_fixed_net();
    let bundle = emit_fixed(&fixed, Target::CortexM4(Chip::Nrf52832)).unwrap();
    check_golden("m4_fixed_conf.h", bundle.code.file("fann_conf.h").unwrap());
    check_golden("m4_fixed_net.h", bundle.code.file("fann_net.h").unwrap());
    check_golden(
        "m4_fixed_inner_loop.c",
        bundle.code.file("fann_inner_loop.c").unwrap(),
    );
}

#[test]
fn golden_wolf8_layerwise_snapshots() {
    // Weight values don't matter for these files: the conf header and
    // the DMA loop depend only on shape, placement and strategy.
    let net = grid_net(&[50, 100, 60, 100, 60, 8], Activation::Tanh, 7);
    let bundle = emit_float(&net, Target::WolfCluster { cores: 8 }, NetRepr::F32, 1.0).unwrap();
    assert!(bundle.code.file("fann_dma.c").is_some());
    check_golden(
        "wolf8_f32_layerwise_conf.h",
        bundle.code.file("fann_conf.h").unwrap(),
    );
    check_golden(
        "wolf8_f32_layerwise_dma.c",
        bundle.code.file("fann_dma.c").unwrap(),
    );
}

#[test]
fn golden_dir_documents_update_path() {
    // The golden directory must exist in-tree (snapshots are committed,
    // not generated on demand in CI).
    assert!(
        golden_path(".").parent().unwrap().is_dir(),
        "rust/tests/golden/ missing — run UPDATE_GOLDEN=1 cargo test --test golden_codegen"
    );
}
