//! Cross-kernel parity: every [`fann_on_mcu::kernels::DenseKernel`]
//! implementation must agree on the same layer —
//!
//! * `ScalarF32` vs `BlockedF32`: within 3e-5 (the blocked kernel only
//!   reassociates float adds),
//! * `SimdF32` vs `ScalarF32`: within the same 3e-5 (16 fixed fma
//!   lanes), plus bitwise invariants of its own — matvec == matmul at
//!   every row-tile setting, forced-scalar == runtime-dispatched, and
//!   (on x86_64) runtime detection actually leaving the scalar tier,
//! * `FixedQ` vs a scalar Q-format oracle (written out longhand here,
//!   against `quantize`'s primitive semantics): bit-exact,
//!
//! across randomized shapes (1..=64 inputs/outputs, batch 1..=16),
//! which exercises full 4-tiles, partial tiles and the `len % 4 != 0`
//! input tail on every axis.

use fann_on_mcu::kernels::{
    autotune, with_forced_level, BlockedF32, DenseKernel, DenseLayerRef, FixedQ, ScalarF32,
    SimdF32, SimdLevel,
};
use fann_on_mcu::quantize::{qmul, quantize, sat_i32};
use fann_on_mcu::util::max_abs_diff;
use fann_on_mcu::util::proptest::{check, ensure};
use fann_on_mcu::util::rng::Rng;

const TOL: f32 = 3e-5;

struct Case {
    n_in: usize,
    n_out: usize,
    n_samples: usize,
    w: Vec<f32>,
    b: Vec<f32>,
    xs: Vec<f32>,
}

fn random_case(rng: &mut Rng) -> Case {
    let n_in = rng.range_usize(1, 64);
    let n_out = rng.range_usize(1, 64);
    let n_samples = rng.range_usize(1, 16);
    Case {
        n_in,
        n_out,
        n_samples,
        w: (0..n_in * n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        b: (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        xs: (0..n_in * n_samples).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
    }
}

#[test]
fn scalar_vs_blocked_matvec_within_tolerance() {
    check("scalar vs blocked matvec", 300, |rng| {
        let c = random_case(rng);
        let layer = DenseLayerRef::new(c.n_in, c.n_out, &c.w, &c.b);
        let x = &c.xs[..c.n_in];
        let mut scalar = vec![0.0f32; c.n_out];
        let mut blocked = vec![0.0f32; c.n_out];
        ScalarF32.matvec(&layer, x, &mut scalar);
        BlockedF32.matvec(&layer, x, &mut blocked);
        let d = max_abs_diff(&scalar, &blocked);
        ensure(
            d <= TOL,
            format!("n_in={} n_out={} diff={d}", c.n_in, c.n_out),
        )
    });
}

#[test]
fn scalar_vs_blocked_matmul_within_tolerance() {
    check("scalar vs blocked matmul", 200, |rng| {
        let c = random_case(rng);
        let layer = DenseLayerRef::new(c.n_in, c.n_out, &c.w, &c.b);
        let mut scalar = vec![0.0f32; c.n_out * c.n_samples];
        let mut blocked = vec![0.0f32; c.n_out * c.n_samples];
        ScalarF32.matmul(&layer, &c.xs, c.n_samples, &mut scalar);
        BlockedF32.matmul(&layer, &c.xs, c.n_samples, &mut blocked);
        let d = max_abs_diff(&scalar, &blocked);
        ensure(
            d <= TOL,
            format!(
                "n_in={} n_out={} n_samples={} diff={d}",
                c.n_in, c.n_out, c.n_samples
            ),
        )
    });
}

/// Scalar Q-format oracle: the longhand FANN semantics, written against
/// the arithmetic primitives only (no kernel code path shared).
fn dense_q_oracle(
    w: &[i32],
    b: &[i32],
    n_in: usize,
    n_out: usize,
    x: &[i32],
    dec: u32,
) -> Vec<i32> {
    let mut out = vec![0i32; n_out];
    for o in 0..n_out {
        let mut acc: i64 = b[o] as i64;
        for i in 0..n_in {
            acc += qmul(w[o * n_in + i], x[i], dec);
        }
        out[o] = sat_i32(acc) as i32;
    }
    out
}

#[test]
fn fixedq_bit_exact_vs_scalar_oracle() {
    check("fixedq vs oracle", 300, |rng| {
        let c = random_case(rng);
        let dec = rng.range_usize(4, 14) as u32;
        let w: Vec<i32> = c.w.iter().map(|&v| quantize(v, dec)).collect();
        let b: Vec<i32> = c.b.iter().map(|&v| quantize(v, dec)).collect();
        let xs: Vec<i32> = c.xs.iter().map(|&v| quantize(v, dec)).collect();
        let layer = DenseLayerRef::new(c.n_in, c.n_out, &w, &b);
        let kernel = FixedQ::new(dec);

        // matvec, per sample.
        for s in 0..c.n_samples {
            let x = &xs[s * c.n_in..(s + 1) * c.n_in];
            let mut got = vec![0i32; c.n_out];
            kernel.matvec(&layer, x, &mut got);
            let want = dense_q_oracle(&w, &b, c.n_in, c.n_out, x, dec);
            ensure(got == want, format!("matvec mismatch sample {s}"))?;
        }

        // batched matmul vs the same oracle.
        let mut got = vec![0i32; c.n_out * c.n_samples];
        kernel.matmul(&layer, &xs, c.n_samples, &mut got);
        for s in 0..c.n_samples {
            let want = dense_q_oracle(
                &w,
                &b,
                c.n_in,
                c.n_out,
                &xs[s * c.n_in..(s + 1) * c.n_in],
                dec,
            );
            ensure(
                got[s * c.n_out..(s + 1) * c.n_out] == want[..],
                format!("matmul mismatch sample {s}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn simd_f32_within_tolerance_of_scalar() {
    // SimdF32 reassociates the float sum into 16 fixed lanes (and the
    // hardware paths mirror the portable lane mirror bit-for-bit), so
    // it gets the same 3e-5 budget the blocked kernel does.
    check("simd_f32 vs scalar", 200, |rng| {
        let c = random_case(rng);
        let layer = DenseLayerRef::new(c.n_in, c.n_out, &c.w, &c.b);
        let mut scalar = vec![0.0f32; c.n_out * c.n_samples];
        let mut simd = vec![0.0f32; c.n_out * c.n_samples];
        ScalarF32.matmul(&layer, &c.xs, c.n_samples, &mut scalar);
        SimdF32.matmul(&layer, &c.xs, c.n_samples, &mut simd);
        let d = max_abs_diff(&scalar, &simd);
        ensure(d <= TOL, format!("matmul n_in={} n_out={} diff={d}", c.n_in, c.n_out))?;
        let x = &c.xs[..c.n_in];
        let mut scalar1 = vec![0.0f32; c.n_out];
        let mut simd1 = vec![0.0f32; c.n_out];
        ScalarF32.matvec(&layer, x, &mut scalar1);
        SimdF32.matvec(&layer, x, &mut simd1);
        let d1 = max_abs_diff(&scalar1, &simd1);
        ensure(d1 <= TOL, format!("matvec n_in={} n_out={} diff={d1}", c.n_in, c.n_out))
    });
}

#[test]
fn simd_f32_matvec_equals_matmul_bitwise_across_tiles() {
    // The row tile is a pure traversal-order knob: every (row, sample)
    // cell is one independent fixed-order dot product, so matmul must
    // reproduce matvec bit-for-bit at every tile setting the autotuner
    // can install.
    let mut rng = Rng::new(0x7F32);
    let saved = autotune::current();
    for tile in [1usize, 2, 4] {
        let mut t = saved;
        t.f32_rows_per_tile = tile;
        autotune::apply(&t);
        for _ in 0..20 {
            let c = random_case(&mut rng);
            let layer = DenseLayerRef::new(c.n_in, c.n_out, &c.w, &c.b);
            let mut mm = vec![0.0f32; c.n_out * c.n_samples];
            SimdF32.matmul(&layer, &c.xs, c.n_samples, &mut mm);
            for s in 0..c.n_samples {
                let mut mv = vec![0.0f32; c.n_out];
                SimdF32.matvec(&layer, &c.xs[s * c.n_in..(s + 1) * c.n_in], &mut mv);
                let col = &mm[s * c.n_out..(s + 1) * c.n_out];
                assert!(
                    mv.iter().zip(col).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "tile={tile} sample={s} n_in={} n_out={}",
                    c.n_in,
                    c.n_out
                );
            }
        }
    }
    autotune::apply(&saved);
}

#[test]
fn simd_f32_forced_scalar_is_bit_identical() {
    // The portable lane mirror runs the exact per-lane mul_add chains
    // the AVX2/NEON paths run, so pinning dispatch to Scalar must not
    // move a single bit.
    let mut rng = Rng::new(0xB17);
    for _ in 0..30 {
        let c = random_case(&mut rng);
        let layer = DenseLayerRef::new(c.n_in, c.n_out, &c.w, &c.b);
        let mut ambient = vec![0.0f32; c.n_out * c.n_samples];
        SimdF32.matmul(&layer, &c.xs, c.n_samples, &mut ambient);
        let forced = with_forced_level(SimdLevel::Scalar, || {
            let mut out = vec![0.0f32; c.n_out * c.n_samples];
            SimdF32.matmul(&layer, &c.xs, c.n_samples, &mut out);
            out
        });
        assert!(
            ambient.iter().zip(&forced).all(|(a, b)| a.to_bits() == b.to_bits()),
            "forced-scalar SimdF32 diverged (n_in={} n_out={} n_samples={})",
            c.n_in,
            c.n_out,
            c.n_samples
        );
    }
}

#[test]
#[cfg(target_arch = "x86_64")]
fn simd_level_is_detected_on_x86_64() {
    // SSE2 is architecturally guaranteed on x86_64: runtime detection
    // must never leave an x86_64 host (CI included) on the scalar tier.
    let f = fann_on_mcu::kernels::cpu_features();
    assert!(
        f.detected == SimdLevel::Sse2 || f.detected == SimdLevel::Avx2,
        "detected {:?}",
        f.detected
    );
    assert!(f.sse2, "SSE2 flag must be set on x86_64");
}

#[test]
fn tail_shapes_are_exercised_explicitly() {
    // Deterministic shape sweep straddling every 4-boundary: the random
    // sweep above almost surely hits these, this makes it certain.
    let mut rng = Rng::new(0x7A17);
    for n_in in [1usize, 2, 3, 4, 5, 7, 8, 9, 63, 64] {
        for n_out in [1usize, 3, 4, 5, 64] {
            for n_samples in [1usize, 3, 4, 5, 16] {
                let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let b: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                let xs: Vec<f32> = (0..n_in * n_samples)
                    .map(|_| rng.range_f32(-1.0, 1.0))
                    .collect();
                let layer = DenseLayerRef::new(n_in, n_out, &w, &b);
                let mut scalar = vec![0.0f32; n_out * n_samples];
                let mut blocked = vec![0.0f32; n_out * n_samples];
                ScalarF32.matmul(&layer, &xs, n_samples, &mut scalar);
                BlockedF32.matmul(&layer, &xs, n_samples, &mut blocked);
                assert!(
                    max_abs_diff(&scalar, &blocked) <= TOL,
                    "n_in={n_in} n_out={n_out} n_samples={n_samples}"
                );
            }
        }
    }
}
