//! Integration suite for the compiled execution plans
//! (`kernels::exec_plan`): compile-from-every-source parity against the
//! dispatch paths over a grid of ragged shapes, the narrow/wide q32
//! kernel selection, and the `simulator::Executable::Compiled` wiring.

use fann_on_mcu::bench::batch::{run_plan_q_rowsplit, run_plan_rowsplit};
use fann_on_mcu::deploy::{self, NetShape};
use fann_on_mcu::fann::{from_float_packed, Activation, FixedNetwork, Network};
use fann_on_mcu::kernels::{PackedWidth, PlanScratch};
use fann_on_mcu::simulator::{self, CostOptions, Executable};
use fann_on_mcu::targets::{DataType, Target};
use fann_on_mcu::util::rng::Rng;

fn net(sizes: &[usize], seed: u64) -> Network {
    let mut rng = Rng::new(seed);
    let mut n = Network::new(sizes, Activation::Tanh, Activation::Sigmoid).unwrap();
    n.randomize(&mut rng, None);
    n
}

/// The shape grid: ragged widths straddling the 4-wide tile and panel
/// boundaries, a single-neuron output, and a deeper stack.
fn shape_grid() -> Vec<Vec<usize>> {
    vec![
        vec![1, 1],
        vec![3, 1],
        vec![4, 4, 4],
        vec![5, 9, 3],
        vec![7, 13, 11, 2],
        vec![16, 8, 8, 16, 4],
        vec![33, 5, 17, 1],
    ]
}

#[test]
fn compiled_plans_match_dispatch_for_every_source_and_shape() {
    for (i, sizes) in shape_grid().into_iter().enumerate() {
        let fnet = net(&sizes, 100 + i as u64);
        let mut rng = Rng::new(50 + i as u64);
        for n_samples in [1usize, 4, 7] {
            let xs: Vec<f32> =
                (0..n_samples * sizes[0]).map(|_| rng.range_f32(-1.0, 1.0)).collect();

            // Float source.
            let plan = fnet.compile_plan();
            assert_eq!(
                plan.run_batch_f32(&xs, n_samples),
                fnet.run_batch(&xs, n_samples),
                "{sizes:?} f32 n={n_samples}"
            );

            // Fixed source.
            let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
            let plan_q = fixed.compile_plan();
            let xq = fixed.quantize_input(&xs);
            assert_eq!(
                plan_q.run_batch_q(&xq, n_samples),
                fixed.run_batch_q(&xq, n_samples),
                "{sizes:?} q32 n={n_samples}"
            );

            // Packed sources.
            for width in [PackedWidth::Q7, PackedWidth::Q15] {
                let (reference, packed) = from_float_packed(&fnet, 1.0, width).unwrap();
                let plan_p = packed.compile_plan();
                let xqp = packed.quantize_input(&xs);
                let got = plan_p.run_batch_q(&xqp, n_samples);
                assert_eq!(
                    got,
                    packed.run_batch_q(&xqp, n_samples),
                    "{sizes:?} {width:?} n={n_samples}"
                );
                // And transitively bit-exact vs the wide FixedQ
                // reference at the same decimal point.
                assert_eq!(
                    got,
                    reference.run_batch_q(&xqp, n_samples),
                    "{sizes:?} {width:?} vs FixedQ n={n_samples}"
                );
            }
        }
    }
}

#[test]
fn plan_reuses_one_flat_scratch_with_no_steady_state_allocation() {
    let fnet = net(&[12, 9, 5], 3);
    let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
    let plan = fixed.compile_plan();
    let mut rng = Rng::new(9);
    let n = 6;
    let xs: Vec<f32> = (0..n * 12).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let xq = fixed.quantize_input(&xs);
    let mut scratch = PlanScratch::new();
    let mut out = vec![0i32; n * plan.num_outputs()];
    plan.run_batch_q_into(&xq, n, &mut scratch, &mut out);
    let want = out.clone();
    // Repeated same-shape runs must neither reallocate nor drift.
    for _ in 0..10 {
        plan.run_batch_q_into(&xq, n, &mut scratch, &mut out);
        assert_eq!(out, want);
    }
}

#[test]
fn q32_wide_path_inputs_stay_bit_exact_through_the_network() {
    // Inputs near the i32 rails force the exact i64 path on layer 0;
    // deeper layers drop back to the narrow kernel after the first
    // activation bounds the values. Every mix must equal FixedQ.
    let fnet = net(&[6, 10, 4], 77);
    let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
    let plan = fixed.compile_plan();
    let huge: Vec<i32> = (0..6)
        .map(|i| match i % 3 {
            0 => i32::MAX - i as i32,
            1 => i32::MIN + 1 + i as i32,
            _ => (1 << 28) + i as i32,
        })
        .collect();
    assert_eq!(plan.run_batch_q(&huge, 1), fixed.run_batch_q(&huge, 1));
    assert!(!plan.narrow_ok(0, &huge));
    // Row-split on the wide path is bit-exact too.
    for workers in [2usize, 5, 8] {
        assert_eq!(
            run_plan_q_rowsplit(&plan, &huge, 1, workers),
            fixed.run_batch_q(&huge, 1),
            "workers={workers}"
        );
    }
}

#[test]
fn compiled_executable_runs_under_deployment_plans() {
    let fnet = net(&[8, 14, 6], 5);
    let shape = NetShape::from(&fnet);
    let x: Vec<f32> = {
        let mut rng = Rng::new(13);
        (0..8).map(|_| rng.range_f32(-1.0, 1.0)).collect()
    };

    // Float compiled plan on the cluster.
    let plan_f = fnet.compile_plan();
    let dp = deploy::plan(&shape, Target::WolfCluster { cores: 8 }, DataType::Float32).unwrap();
    let want = simulator::simulate(&dp, &Executable::Float(&fnet), &x, CostOptions::default())
        .unwrap();
    let got =
        simulator::simulate(&dp, &Executable::Compiled(&plan_f), &x, CostOptions::default())
            .unwrap();
    assert_eq!(got.outputs, want.outputs);
    assert_eq!(got.breakdown.total(), want.breakdown.total());
    assert_eq!(got.energy_uj, want.energy_uj);

    // Fixed compiled plan on the FC, batched.
    let fixed = FixedNetwork::from_float(&fnet, 1.0).unwrap();
    let plan_q = fixed.compile_plan();
    let dq = deploy::plan(&shape, Target::WolfFc, DataType::Fixed).unwrap();
    let mut rng = Rng::new(21);
    let n = 5;
    let xs: Vec<f32> = (0..n * 8).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let want_b =
        simulator::simulate_batch(&dq, &Executable::Fixed(&fixed), &xs, n, CostOptions::default())
            .unwrap();
    let got_b = simulator::simulate_batch(
        &dq,
        &Executable::Compiled(&plan_q),
        &xs,
        n,
        CostOptions::default(),
    )
    .unwrap();
    assert_eq!(got_b.outputs, want_b.outputs);
    assert_eq!(got_b.total_seconds, want_b.total_seconds);
}

#[test]
fn rowsplit_composes_with_sample_chunk_parallelism() {
    // The two parallelism axes answer different questions but must
    // agree bit for bit: row-split (intra-layer) and the inter-sample
    // chunked driver, on the same plan-equivalent network.
    let fnet = net(&[10, 24, 16, 8], 55);
    let plan = fnet.compile_plan();
    let mut rng = Rng::new(2);
    let n = 17;
    let xs: Vec<f32> = (0..n * 10).map(|_| rng.range_f32(-1.0, 1.0)).collect();
    let serial = plan.run_batch_f32(&xs, n);
    assert_eq!(
        fann_on_mcu::bench::batch::run_batch_parallel(&fnet, &xs, n, 4),
        serial,
        "inter-sample driver"
    );
    assert_eq!(run_plan_rowsplit(&plan, &xs, n, 4), serial, "intra-layer driver");
}
